//! The paper's accurate analytic accelerator model (§3, Formulas 1–15), the
//! XFER modifications (§4, Formulas 16–22), bottleneck detection
//! (Corollary 1), and the optimistic roofline baseline model of
//! Zhang et al. FPGA'15 [14] used for the accuracy comparisons
//! (Figures 2 and 14).
//!
//! All latencies are in **accelerator clock cycles** (100 MHz for f32,
//! 200 MHz for fx16 — `Precision::cycles_to_ms` converts).

pub mod baseline;
mod bottleneck;
mod design;
mod latency;
mod resources;
mod xfer;

pub use bottleneck::{detect, Bottleneck};
pub use design::Design;
pub use latency::{layer_latency, network_latency, LayerLatency, SliceDims};
pub use resources::{check_feasible, is_feasible, usage, ResourceUsage};
pub use xfer::{
    xfer_layer_latency, xfer_layer_latency_ref, xfer_network_latency, xfer_network_latency_ref,
    ClusterLayerLatency, XferMode,
};
