//! Performance-bottleneck detection (§3 ③, Corollary 1).

use super::LayerLatency;

/// Where a design's time goes, per Corollary 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    /// `Lat2` dominated by `tO` — OFM store bound.
    OfmStore,
    /// `Lat1` dominated by `tI` — IFM load bound.
    IfmLoad,
    /// `Lat1` dominated by `tW` — weight load bound.
    WeightLoad,
    /// `Lat1` dominated by an inter-FPGA ring (XFER only).
    InterFpga,
    /// `Lat1` dominated by `tComp` — "we have fully utilized the involved
    /// computation resource".
    Compute,
}

impl Bottleneck {
    /// Human-readable label matching Table 4's "Bound" column.
    pub fn label(self) -> &'static str {
        match self {
            Bottleneck::OfmStore => "OFM",
            Bottleneck::IfmLoad => "IFM",
            Bottleneck::WeightLoad => "Weight",
            Bottleneck::InterFpga => "Inter-FPGA",
            Bottleneck::Compute => "Comp.",
        }
    }
}

/// Apply Corollary 1 to a latency breakdown. Priority order follows the
/// corollary: check `Lat2`'s OFM domination first, then the `Lat1` terms.
pub fn detect(ll: &LayerLatency) -> Bottleneck {
    if ll.lat2 == ll.t_o && ll.t_o > ll.trips_n * ll.lat1 {
        return Bottleneck::OfmStore;
    }
    // Within Lat1, report the largest term; compute wins ties (a fully
    // overlapped design is compute-bound by construction).
    let max = ll.lat1;
    if ll.t_comp == max {
        Bottleneck::Compute
    } else if ll.t_i == max {
        Bottleneck::IfmLoad
    } else if ll.t_w == max {
        Bottleneck::WeightLoad
    } else {
        Bottleneck::InterFpga
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::{layer_latency, Design};
    use crate::model::ConvLayer;

    fn layer() -> ConvLayer {
        ConvLayer::conv("x", 1, 256, 256, 26, 26, 3)
    }

    #[test]
    fn compute_bound() {
        let d = Design::fixed16(16, 8, 13, 13); // small array, default streams
        let b = detect(&layer_latency(&layer(), &d));
        assert_eq!(b, Bottleneck::Compute);
    }

    #[test]
    fn weight_bound() {
        // Big array, starved weight stream.
        let d = Design::fixed16(128, 16, 13, 13).with_streams(8, 1, 8);
        let b = detect(&layer_latency(&layer(), &d));
        assert_eq!(b, Bottleneck::WeightLoad);
    }

    #[test]
    fn ifm_bound() {
        // 1×1 kernel: tComp = Tr·Tc tiny; starve the IFM stream.
        let l = ConvLayer::conv("x", 1, 64, 512, 26, 26, 1);
        let d = Design::fixed16(16, 64, 13, 13).with_streams(1, 8, 8);
        let b = detect(&layer_latency(&l, &d));
        assert_eq!(b, Bottleneck::IfmLoad);
    }

    #[test]
    fn ofm_bound() {
        // Few input channels (1 inner trip), starved output stream.
        let l = ConvLayer::conv("x", 1, 512, 4, 26, 26, 1);
        let d = Design::fixed16(128, 4, 13, 13).with_streams(8, 8, 1);
        let b = detect(&layer_latency(&l, &d));
        assert_eq!(b, Bottleneck::OfmStore);
    }

    #[test]
    fn labels() {
        assert_eq!(Bottleneck::Compute.label(), "Comp.");
        assert_eq!(Bottleneck::WeightLoad.label(), "Weight");
    }
}
