//! The roofline model of Zhang et al. FPGA'15 [14] — the **inaccurate**
//! baseline the paper's Challenge 1 (Figure 2) and Figure 14 compare
//! against.
//!
//! [14] predicts layer latency as the max of pure compute time and total
//! off-chip traffic over **aggregate** bandwidth, assuming uninterrupted,
//! perfectly overlapped memory access. It ignores (a) the per-phase
//! synchronization of a double-buffered engine (`Lat1/Lat2`'s `max{}`
//! structure) and (b) that each data stream only gets its own AXI ports.
//! Both omissions make it optimistic exactly when a design is
//! communication-bound — the divergence Figure 14 shows at ⟨10,22⟩ (18.49%)
//! and ⟨8,32⟩ (45.47%), and its agreement at compute-bound ⟨12,16⟩.

use super::Design;
use crate::model::ConvLayer;

/// FPGA15 roofline prediction for one layer.
#[derive(Debug, Clone, Copy)]
pub struct RooflinePrediction {
    /// Pure compute cycles (engine invocations × tComp).
    pub compute_cycles: u64,
    /// Total off-chip traffic in elements (their α·B terms).
    pub traffic_elems: u64,
    /// Traffic served at the full bus width (words/cycle).
    pub memory_cycles: u64,
    /// Predicted latency: max of the two roofs.
    pub cycles: u64,
    /// Computation-to-communication ratio (their CTC, ops per element).
    pub ctc: f64,
}

/// Evaluate the [14] model for `layer` under `design`, with the full memory
/// bus (`bus_words_per_cycle` = 𝕎/BITs) behind the accelerator.
pub fn fpga15_latency(layer: &ConvLayer, d: &Design, bus_words_per_cycle: u64) -> RooflinePrediction {
    let (m, n) = (layer.m_per_group(), layer.n_per_group());
    let tm = d.tm.min(m).max(1);
    let tn = d.tn.min(n).max(1);
    let tr = d.tr.min(layer.r).max(1);
    let tc = d.tc.min(layer.c).max(1);
    let k2 = layer.k * layer.k;

    let trips_n = n.div_ceil(tn);
    let trips_outer = layer.b
        * layer.r.div_ceil(tr)
        * layer.c.div_ceil(tc)
        * m.div_ceil(tm)
        * layer.groups;

    // Their compute model matches eq 11's engine: one invocation per
    // (outer × inner) trip, K·K·Tr·Tc cycles each.
    let compute_cycles = trips_outer * trips_n * (k2 * tr * tc);

    // Their traffic model: every inner trip loads an IFM tile + weight
    // tile; every outer trip stores an OFM tile.
    let traffic_in = trips_outer * trips_n * (tn * tr * tc + tm * tn * k2);
    let traffic_out = trips_outer * (tm * tr * tc);
    let traffic_elems = traffic_in + traffic_out;

    let memory_cycles = traffic_elems.div_ceil(bus_words_per_cycle);
    let cycles = compute_cycles.max(memory_cycles);
    let ctc = (2 * layer.macs()) as f64 / traffic_elems as f64;

    RooflinePrediction {
        compute_cycles,
        traffic_elems,
        memory_cycles,
        cycles,
        ctc,
    }
}

/// Attainable GOPS under the [14] roofline (Figure 2's y-axis) given peak
/// memory bandwidth in elements/cycle.
pub fn attainable_gops(
    layer: &ConvLayer,
    d: &Design,
    bus_words_per_cycle: u64,
) -> f64 {
    let p = fpga15_latency(layer, d, bus_words_per_cycle);
    let secs = p.cycles as f64 / (d.precision.freq_mhz() as f64 * 1e6);
    layer.ops() as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::layer_latency;
    use crate::model::ConvLayer;

    fn layer() -> ConvLayer {
        // AlexNet conv5-like (the Figure 2 subject).
        ConvLayer::conv("conv5", 1, 256, 192, 13, 13, 3).grouped(2)
    }

    #[test]
    fn optimistic_vs_accurate_when_comm_bound() {
        // Communication-bound design: [14] must predict FEWER cycles than
        // the accurate model (it assumes perfect overlap + full bus).
        let d = Design::float32(8, 32, 13, 13);
        let ours = layer_latency(&layer(), &d).lat;
        let theirs = fpga15_latency(&layer(), &d, 16).cycles;
        assert!(
            theirs < ours,
            "fpga15 {theirs} should be optimistic vs ours {ours}"
        );
    }

    #[test]
    fn agrees_when_compute_bound() {
        // Compute-bound design ⟨12,16⟩-style: both models ≈ compute cycles.
        let d = Design::float32(12, 16, 13, 13);
        let ours = layer_latency(&layer(), &d).lat as f64;
        let theirs = fpga15_latency(&layer(), &d, 16).cycles as f64;
        let dev = (ours - theirs).abs() / ours;
        assert!(dev < 0.05, "deviation {dev}");
    }

    #[test]
    fn ctc_positive_and_finite() {
        let d = Design::float32(10, 22, 13, 13);
        let p = fpga15_latency(&layer(), &d, 16);
        assert!(p.ctc > 0.0 && p.ctc.is_finite());
        assert_eq!(p.cycles, p.compute_cycles.max(p.memory_cycles));
    }

    #[test]
    fn attainable_gops_bounded_by_peak() {
        let d = Design::float32(12, 16, 13, 13);
        let g = attainable_gops(&layer(), &d, 16);
        assert!(g <= d.peak_gops() * 1.01, "{g} > peak {}", d.peak_gops());
    }
}
