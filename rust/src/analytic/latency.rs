//! The single-FPGA latency model (Formulas 8–15, Figure 6).
//!
//! The accelerator is a tiled, double-buffered engine: per inner trip it
//! loads an IFM tile and a weight tile while computing on the previous pair
//! (`Lat1 = max{tComp, tI, tW}`, eq 12); OFM write-back overlaps the
//! ⌈N/Tn⌉-trip accumulation (`Lat2 = max{⌈N/Tn⌉·Lat1, tO}`, eq 13); the
//! outer loops multiply (eq 14).

use super::Design;
use crate::model::ConvLayer;

/// Full latency breakdown of one layer under one design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerLatency {
    /// Clamped tile dims actually in play for this layer.
    pub tm: u64,
    pub tn: u64,
    pub tr: u64,
    pub tc: u64,
    /// IFM tile load cycles (eq 8).
    pub t_i: u64,
    /// Weight tile load cycles (eq 9, or 16 under XFER).
    pub t_w: u64,
    /// OFM tile store cycles (eq 10).
    pub t_o: u64,
    /// Compute cycles of one engine invocation (eq 11).
    pub t_comp: u64,
    /// Worst inter-FPGA channel latency folded into Lat1 (eqs 17/19; 0 when
    /// XFER is off).
    pub t_b2b: u64,
    /// Eq 12 (18/21 under XFER).
    pub lat1: u64,
    /// Eq 13.
    pub lat2: u64,
    /// Inner trip count ⌈N/Tn⌉.
    pub trips_n: u64,
    /// Outer trip count B·⌈R/Tr⌉·⌈C/Tc⌉·⌈M/Tm⌉ (× groups).
    pub trips_outer: u64,
    /// Eq 14 — total layer cycles.
    pub lat: u64,
}

impl LayerLatency {
    /// Effective GOPS this layer achieves under the design.
    pub fn gops(&self, layer: &ConvLayer, freq_mhz: u64) -> f64 {
        layer.ops() as f64 / (self.lat as f64 / (freq_mhz as f64 * 1e6)) / 1e9
    }
}

/// Everything eqs 8–14 need of a (sub-)layer, as a plain copyable value.
///
/// The DSE hot path evaluates millions of candidate × slice shapes; a
/// `ConvLayer` clone per evaluation (String name included) would dominate
/// the search time, so the closed-form paths route through this type and
/// never touch the heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SliceDims {
    pub b: u64,
    pub m: u64,
    pub n: u64,
    pub r: u64,
    pub c: u64,
    pub k: u64,
    pub groups: u64,
}

impl SliceDims {
    /// The dims of a full (un-sliced) layer.
    pub fn of(layer: &ConvLayer) -> Self {
        SliceDims {
            b: layer.b,
            m: layer.m,
            n: layer.n,
            r: layer.r,
            c: layer.c,
            k: layer.k,
            groups: layer.groups,
        }
    }

    /// OFM channels produced by one group (cf. `ConvLayer::m_per_group`).
    pub fn m_per_group(&self) -> u64 {
        self.m / self.groups
    }

    /// IFM channels seen by one group (cf. `ConvLayer::n_per_group`).
    pub fn n_per_group(&self) -> u64 {
        self.n / self.groups
    }
}

/// Evaluate eqs 8–14 for `layer` under `design` (single FPGA, no XFER).
pub fn layer_latency(layer: &ConvLayer, d: &Design) -> LayerLatency {
    layer_latency_scaled(layer, d, 1, 1, 0)
}

/// `slice_latency_scaled` on a full layer's dims (see `analytic::xfer`).
pub(super) fn layer_latency_scaled(
    layer: &ConvLayer,
    d: &Design,
    w_div: u64,
    i_div: u64,
    t_b2b: u64,
) -> LayerLatency {
    slice_latency_scaled(&SliceDims::of(layer), d, w_div, i_div, t_b2b)
}

/// Core evaluation shared with the XFER model (`analytic::xfer`):
/// `w_div` divides the weight-load latency (eq 16's `Pb·Pr·Pc`),
/// `i_div` divides the IFM-load latency (eq 20's `Pm`),
/// `t_b2b` is the worst inter-FPGA channel term entering Lat1 (eqs 18/21).
pub(super) fn slice_latency_scaled(
    s: &SliceDims,
    d: &Design,
    w_div: u64,
    i_div: u64,
    t_b2b: u64,
) -> LayerLatency {
    let (m, n) = (s.m_per_group(), s.n_per_group());
    // Tiles never exceed the layer dims they tile.
    let tm = d.tm.min(m).max(1);
    let tn = d.tn.min(n).max(1);
    let tr = d.tr.min(s.r).max(1);
    let tc = d.tc.min(s.c).max(1);
    let k2 = s.k * s.k;

    // Eqs 8–11 (eq 16/20 generalization via the divisors).
    let t_i = (tn * tr * tc).div_ceil(d.ip * i_div);
    let t_w = (tm * tn * k2).div_ceil(d.wp * w_div);
    let t_o = (tm * tr * tc).div_ceil(d.op);
    let t_comp = k2 * tr * tc;

    // Eq 12 / 18 / 21.
    let lat1 = t_comp.max(t_i).max(t_w).max(t_b2b);
    // Eq 13.
    let trips_n = n.div_ceil(tn);
    let lat2 = (trips_n * lat1).max(t_o);
    // Eq 14 — outer trips; grouped convs run the engine once per group.
    let trips_outer = s.b * s.r.div_ceil(tr) * s.c.div_ceil(tc) * m.div_ceil(tm) * s.groups;
    let lat = trips_outer * lat2 + t_o + lat1;

    LayerLatency {
        tm,
        tn,
        tr,
        tc,
        t_i,
        t_w,
        t_o,
        t_comp,
        t_b2b,
        lat1,
        lat2,
        trips_n,
        trips_outer,
        lat,
    }
}

/// Sum of eq 14 over all conv layers of a network (uniform design, §4.6).
/// Repeated layer shapes (VGG16's stacked 3×3 blocks) are evaluated once
/// and multiplied — u64 sums are exact, so the value is bit-identical to
/// the naive per-layer sum.
pub fn network_latency(net: &crate::model::Network, d: &Design) -> u64 {
    net.conv_shape_classes()
        .iter()
        .map(|&(l, count)| count * layer_latency(l, d).lat)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    /// AlexNet conv5 as a free-standing layer (the Figure 2 workload).
    fn conv5() -> ConvLayer {
        zoo::alexnet().layers[4].clone()
    }

    #[test]
    fn tiles_clamped_to_layer() {
        let l = conv5(); // grouped: m=128, n=192 per group
        let d = Design::float32(256, 256, 64, 64);
        let ll = layer_latency(&l, &d);
        assert_eq!(ll.tm, 128);
        assert_eq!(ll.tn, 192);
        assert_eq!(ll.tr, 13);
        assert_eq!(ll.tc, 13);
    }

    #[test]
    fn compute_bound_design_dominated_by_tcomp() {
        // Small stream widths but tiny tiles → compute dominates.
        let l = ConvLayer::conv("x", 1, 64, 64, 32, 32, 3);
        let d = Design::fixed16(8, 8, 32, 32);
        let ll = layer_latency(&l, &d);
        assert_eq!(ll.t_comp, 9 * 32 * 32);
        assert!(ll.t_comp >= ll.t_i && ll.t_comp >= ll.t_w);
        assert_eq!(ll.lat1, ll.t_comp);
    }

    #[test]
    fn comm_bound_design_dominated_by_memory() {
        // Huge MAC array, narrow streams → weight load dominates Lat1.
        let l = ConvLayer::conv("x", 1, 256, 256, 13, 13, 3);
        let d = Design::fixed16(128, 16, 13, 13).with_streams(1, 1, 1);
        let ll = layer_latency(&l, &d);
        assert!(ll.t_w > ll.t_comp, "{:?}", ll);
        assert_eq!(ll.lat1, ll.t_w);
    }

    #[test]
    fn eq14_structure() {
        let l = ConvLayer::conv("x", 2, 100, 50, 26, 26, 3);
        let d = Design::fixed16(32, 16, 13, 13);
        let ll = layer_latency(&l, &d);
        assert_eq!(ll.trips_n, 50u64.div_ceil(16));
        assert_eq!(ll.trips_outer, 2 * 2 * 2 * 100u64.div_ceil(32));
        assert_eq!(ll.lat, ll.trips_outer * ll.lat2 + ll.t_o + ll.lat1);
    }

    #[test]
    fn latency_monotone_in_stream_width() {
        // More AXI streams can never hurt.
        let l = conv5();
        let d1 = Design::fixed16(64, 24, 13, 13).with_streams(2, 2, 2);
        let d2 = Design::fixed16(64, 24, 13, 13).with_streams(8, 8, 8);
        assert!(layer_latency(&l, &d2).lat <= layer_latency(&l, &d1).lat);
    }

    #[test]
    fn network_latency_sums_layers() {
        let net = zoo::alexnet();
        let d = Design::fixed16(64, 24, 13, 13);
        let total = network_latency(&net, &d);
        let by_hand: u64 = net.conv_layers().map(|l| layer_latency(l, &d).lat).sum();
        assert_eq!(total, by_hand);
        assert!(total > 0);
    }

    #[test]
    fn grouped_layer_runs_engine_per_group() {
        let full = ConvLayer::conv("x", 1, 256, 96, 27, 27, 5);
        let grp = ConvLayer::conv("x", 1, 256, 96, 27, 27, 5).grouped(2);
        let d = Design::fixed16(64, 24, 13, 13);
        // Grouped variant halves per-group channels but doubles engine runs;
        // latency should be within 2× of full either way, not wildly off.
        let lf = layer_latency(&full, &d).lat as f64;
        let lg = layer_latency(&grp, &d).lat as f64;
        assert!(lg / lf < 1.5 && lf / lg < 2.5, "lf={lf} lg={lg}");
    }
}
