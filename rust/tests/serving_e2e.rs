//! Serving-path end-to-end tests with a stub backend: correctness under
//! load, batching behaviour, deadline handling, plan-driven routing
//! (multi-model lanes and replica sets), and failure injection. Every
//! server here is a `start_plan` server — a single-model server is a
//! one-lane plan.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, PlanRouter, RoutePolicy, Server,
    ServerConfig,
};
use superlip::util::SplitMix64;

/// Stub: logits[c] = image checksum * (c+1); optional failure injection.
struct Stub {
    elems: usize,
    classes: usize,
    max_batch: usize,
    delay: Duration,
    fail_every: Option<u64>,
    calls: AtomicU64,
    served: Arc<AtomicUsize>,
}

impl InferBackend for Stub {
    fn image_elems(&self) -> usize {
        self.elems
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn infer(&self, images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        let call = self.calls.fetch_add(1, Ordering::Relaxed);
        if let Some(k) = self.fail_every {
            if call % k == k - 1 {
                return Err(superlip::Error::Runtime("injected failure".into()));
            }
        }
        std::thread::sleep(self.delay);
        self.served.fetch_add(n, Ordering::Relaxed);
        let mut out = Vec::with_capacity(n * self.classes);
        for i in 0..n {
            let sum: f32 = images[i * self.elems..(i + 1) * self.elems].iter().sum();
            for c in 0..self.classes {
                out.push(sum * (c + 1) as f32);
            }
        }
        Ok(out)
    }
}

/// A single-model server as a one-lane plan (the single entry point).
fn single(factories: Vec<BackendFactory>, cfg: ServerConfig) -> Server {
    Server::start_plan(
        vec![LaneSpec {
            model: "default".into(),
            factories,
            batcher: cfg.batcher,
        }],
        cfg,
    )
}

fn factory(
    delay_ms: u64,
    fail_every: Option<u64>,
    served: Arc<AtomicUsize>,
) -> BackendFactory {
    Box::new(move || {
        Ok(Box::new(Stub {
            elems: 8,
            classes: 4,
            max_batch: 4,
            delay: Duration::from_millis(delay_ms),
            fail_every,
            calls: AtomicU64::new(0),
            served,
        }) as Box<dyn InferBackend>)
    })
}

#[test]
fn sustained_load_all_answers_correct() {
    let served = Arc::new(AtomicUsize::new(0));
    let srv = single(
        vec![factory(0, None, served.clone()), factory(0, None, served.clone())],
        ServerConfig::default(),
    );
    let mut rng = SplitMix64::new(99);
    let mut expect = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..200 {
        let img: Vec<f32> = (0..8).map(|_| rng.signed_unit()).collect();
        let sum: f32 = img.iter().sum();
        expect.push(sum);
        rxs.push(srv.submit(img).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.logits.len(), 4);
        assert!((r.logits[0] - expect[i]).abs() < 1e-5, "request {i}");
        assert!((r.logits[3] - 4.0 * expect[i]).abs() < 1e-4);
    }
    let m = srv.shutdown();
    assert_eq!(m.completed(), 200);
    assert_eq!(served.load(Ordering::Relaxed), 200);
}

#[test]
fn batching_reduces_backend_calls() {
    let served = Arc::new(AtomicUsize::new(0));
    let mut cfg = ServerConfig::default();
    cfg.batcher.window = Duration::from_millis(30);
    cfg.batcher.max_batch = 4;
    let srv = single(vec![factory(2, None, served.clone())], cfg);
    let rxs: Vec<_> = (0..16).map(|_| srv.submit(vec![1.0; 8]).unwrap()).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    let m = srv.shutdown();
    assert!(
        m.mean_batch() > 1.5,
        "window should aggregate: mean batch {}",
        m.mean_batch()
    );
}

#[test]
fn failure_injection_drops_only_affected_batch() {
    let served = Arc::new(AtomicUsize::new(0));
    let mut cfg = ServerConfig::default();
    cfg.batcher.max_batch = 1; // one call per request → failures isolate
    let srv = single(vec![factory(0, Some(5), served.clone())], cfg);
    let rxs: Vec<_> = (0..20).map(|_| srv.submit(vec![1.0; 8]).unwrap()).collect();
    let mut ok = 0;
    let mut dropped = 0;
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(_) => ok += 1,
            Err(_) => dropped += 1,
        }
    }
    let m = srv.shutdown();
    // Every 5th call fails → 4 drops out of 20.
    assert_eq!(dropped, 4, "ok={ok} dropped={dropped}");
    assert_eq!(m.completed(), 16);
}

#[test]
fn deadlines_tracked_under_slow_backend() {
    let served = Arc::new(AtomicUsize::new(0));
    let srv = single(vec![factory(30, None, served)], ServerConfig::default());
    let tight = srv
        .submit_to("default", vec![0.0; 8], Duration::from_millis(1))
        .unwrap();
    let loose = srv
        .submit_to("default", vec![0.0; 8], Duration::from_secs(30))
        .unwrap();
    assert!(!tight.recv_timeout(Duration::from_secs(10)).unwrap().deadline_met);
    assert!(loose.recv_timeout(Duration::from_secs(10)).unwrap().deadline_met);
    let m = srv.shutdown();
    assert_eq!(m.deadline_misses(), 1);
}

/// A lane over the shared stub with its own class count, so responses
/// prove which model's backend served them.
fn lane(
    model: &str,
    classes: usize,
    delay_ms: u64,
    served: Arc<AtomicUsize>,
) -> LaneSpec {
    LaneSpec {
        model: model.into(),
        factories: vec![Box::new(move || {
            Ok(Box::new(Stub {
                elems: 8,
                classes,
                max_batch: 4,
                delay: Duration::from_millis(delay_ms),
                fail_every: None,
                calls: AtomicU64::new(0),
                served,
            }) as Box<dyn InferBackend>)
        }) as BackendFactory],
        batcher: BatcherConfig::default(),
    }
}

#[test]
fn plan_router_dispatches_mixed_traffic_to_the_right_backend() {
    // Two models on one server: every response must come from the lane
    // owning the request's model (distinct class counts + checksums), and
    // per-lane metrics must add up to the aggregate.
    let served_a = Arc::new(AtomicUsize::new(0));
    let served_v = Arc::new(AtomicUsize::new(0));
    let srv = Server::start_plan(
        vec![
            lane("alexnet", 3, 0, served_a.clone()),
            lane("vgg16", 5, 0, served_v.clone()),
        ],
        ServerConfig::default(),
    );
    let d = Duration::from_secs(10);
    let mut rng = SplitMix64::new(17);
    let mut pending = Vec::new();
    for i in 0..60 {
        let model = if i % 3 == 0 { "vgg16" } else { "alexnet" };
        let img: Vec<f32> = (0..8).map(|_| rng.signed_unit()).collect();
        let sum: f32 = img.iter().sum();
        pending.push((model, sum, srv.submit_to(model, img, d).unwrap()));
    }
    for (model, sum, rx) in pending {
        let r = rx.recv_timeout(d).unwrap();
        let want_classes = if model == "vgg16" { 5 } else { 3 };
        assert_eq!(r.logits.len(), want_classes, "{model} answered by wrong lane");
        assert!((r.logits[0] - sum).abs() < 1e-4);
    }
    assert!(srv.submit_to("resnet", vec![0.0; 8], d).is_err(), "unplanned model rejected");
    let (alex_lane, vgg_lane) = (srv.lane_metrics(0), srv.lane_metrics(1));
    let m = srv.shutdown();
    assert_eq!(m.completed(), 60);
    assert_eq!(alex_lane.completed(), 40);
    assert_eq!(vgg_lane.completed(), 20);
    assert_eq!(served_a.load(Ordering::Relaxed), 40);
    assert_eq!(served_v.load(Ordering::Relaxed), 20);
}

#[test]
fn plan_router_spreads_one_model_across_replica_lanes() {
    // Two replica sub-clusters of the same model behind one name: the
    // plan router must use both under load and lose nothing.
    let served_0 = Arc::new(AtomicUsize::new(0));
    let served_1 = Arc::new(AtomicUsize::new(0));
    let mk = |served: Arc<AtomicUsize>| {
        let mut l = lane("alexnet", 4, 3, served);
        l.batcher.max_batch = 1; // per-request dispatch → both lanes engage
        l
    };
    let srv = Server::start_plan(
        vec![mk(served_0.clone()), mk(served_1.clone())],
        ServerConfig::default(),
    );
    let d = Duration::from_secs(10);
    let rxs: Vec<_> = (0..20)
        .map(|_| srv.submit_to("alexnet", vec![1.0; 8], d).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_timeout(d).unwrap();
    }
    assert_eq!(srv.lane_load().iter().sum::<u64>(), 0, "nothing outstanding");
    srv.shutdown();
    let (a, b) = (served_0.load(Ordering::Relaxed), served_1.load(Ordering::Relaxed));
    assert_eq!(a + b, 20);
    assert!(a > 0 && b > 0, "least-outstanding must engage both replicas: {a}/{b}");
}

#[test]
fn router_balances_two_clusters() {
    // A standalone PlanRouter over two independent servers (two simulated
    // XFER clusters serving the same model): one route-table entry whose
    // lane set spans both clusters.
    let served_a = Arc::new(AtomicUsize::new(0));
    let served_b = Arc::new(AtomicUsize::new(0));
    let srv_a = single(vec![factory(1, None, served_a.clone())], ServerConfig::default());
    let srv_b = single(vec![factory(1, None, served_b.clone())], ServerConfig::default());
    let router = PlanRouter::with_routes(RoutePolicy::RoundRobin, 2, [("m", vec![0, 1])]);

    let mut rxs = Vec::new();
    for _ in 0..40 {
        let replica = router.route("m").unwrap();
        let srv = if replica == 0 { &srv_a } else { &srv_b };
        rxs.push((replica, srv.submit(vec![1.0; 8]).unwrap()));
    }
    for (replica, rx) in rxs {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        router.complete(replica);
    }
    srv_a.shutdown();
    srv_b.shutdown();
    let a = served_a.load(Ordering::Relaxed);
    let b = served_b.load(Ordering::Relaxed);
    assert_eq!(a + b, 40);
    assert_eq!(a, 20, "round-robin must split evenly: {a}/{b}");
    assert_eq!(router.load().iter().sum::<u64>(), 0);
}

#[test]
fn throughput_scales_with_workers() {
    // Two workers should serve a fixed load roughly 2x faster than one.
    let run = |workers: usize| {
        let served = Arc::new(AtomicUsize::new(0));
        let mut cfg = ServerConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.batcher.window = Duration::from_micros(1);
        let srv = single(
            (0..workers).map(|_| factory(4, None, served.clone())).collect(),
            cfg,
        );
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..24).map(|_| srv.submit(vec![0.0; 8]).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let el = t0.elapsed();
        srv.shutdown();
        el
    };
    let one = run(1);
    let two = run(2);
    assert!(
        two.as_secs_f64() < one.as_secs_f64() * 0.75,
        "1w={one:?} 2w={two:?}"
    );
}
