//! Migration safety: every request submitted across a live plan
//! migration gets EXACTLY ONE response — nothing dropped, nothing
//! answered twice — while lanes are added, derouted, drained, and reaped
//! under concurrent traffic (the control plane's hitless-handoff
//! contract).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, Server, ServerConfig,
};

/// Deterministic stub: logits[0] = sum(image) + generation tag.
struct Stub {
    delay: Duration,
    tag: f32,
}

impl InferBackend for Stub {
    fn image_elems(&self) -> usize {
        4
    }
    fn classes(&self) -> usize {
        2
    }
    fn max_batch(&self) -> usize {
        3
    }
    fn infer(&self, images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = Vec::with_capacity(n * 2);
        for i in 0..n {
            let s: f32 = images[i * 4..(i + 1) * 4].iter().sum();
            out.push(s);
            out.push(self.tag);
        }
        Ok(out)
    }
}

fn lane(model: &str, delay: Duration, tag: f32) -> LaneSpec {
    LaneSpec {
        model: model.into(),
        factories: vec![Box::new(move || {
            Ok(Box::new(Stub { delay, tag }) as Box<dyn InferBackend>)
        }) as BackendFactory],
        batcher: BatcherConfig {
            max_batch: 3,
            window: Duration::from_micros(300),
            deadline_margin: Duration::from_micros(300),
            ..BatcherConfig::default()
        },
    }
}

/// The headline property: N submitter threads fire continuously while the
/// main thread churns through generations of make-before-break
/// migrations; afterwards every submitted request has exactly one
/// response and the server's books balance.
#[test]
fn every_request_gets_exactly_one_response_across_migrations() {
    const SUBMITTERS: usize = 3;
    const PER_SUBMITTER: usize = 120;
    const MIGRATIONS: usize = 12;

    let srv = Arc::new(Server::start_plan(
        vec![lane("m", Duration::from_micros(400), 0.0)],
        ServerConfig::default(),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let submitted = Arc::new(AtomicUsize::new(0));
    let refused = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::new();
    for sid in 0..SUBMITTERS {
        let srv = srv.clone();
        let submitted = submitted.clone();
        let refused = refused.clone();
        handles.push(std::thread::spawn(move || {
            let mut responses = Vec::new();
            for i in 0..PER_SUBMITTER {
                let v = (sid * PER_SUBMITTER + i) as f32;
                match srv.submit_to("m", vec![v, 0.0, 0.0, 0.0], Duration::from_secs(30)) {
                    Ok(rx) => {
                        submitted.fetch_add(1, Ordering::Relaxed);
                        responses.push((v, rx));
                    }
                    Err(_) => {
                        // Make-before-break means this should never
                        // happen; count it so the assertion below names
                        // the failure mode instead of silently passing.
                        refused.fetch_add(1, Ordering::Relaxed);
                    }
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            // Exactly one response per accepted request, with the right
            // payload, then a closed channel (a second response would
            // still be buffered — try_recv catches duplicates).
            let mut got = 0usize;
            for (v, rx) in responses {
                let r = rx
                    .recv_timeout(Duration::from_secs(20))
                    .unwrap_or_else(|e| panic!("request {v} lost in migration: {e}"));
                assert_eq!(r.logits[0], v, "response routed back to the wrong request");
                got += 1;
                assert!(
                    rx.try_recv().is_err(),
                    "request {v} answered more than once"
                );
            }
            got
        }));
    }

    // Churn migrations while the submitters run: add the replacement (new
    // generation tag), then drain the old lane to nothing.
    let migrator = {
        let srv = srv.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut old = 0usize;
            for gen in 0..MIGRATIONS {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let fresh = srv.add_lane(lane(
                    "m",
                    Duration::from_micros(if gen % 2 == 0 { 900 } else { 300 }),
                    (gen + 1) as f32,
                ));
                srv.retire_lane(old).expect("old lane was live");
                old = fresh;
                std::thread::sleep(Duration::from_millis(8));
            }
            old
        })
    };

    let mut total = 0usize;
    for h in handles {
        total += h.join().expect("submitter panicked");
    }
    stop.store(true, Ordering::Relaxed);
    migrator.join().expect("migrator panicked");

    assert_eq!(refused.load(Ordering::Relaxed), 0, "submit refused mid-migration");
    assert_eq!(total, SUBMITTERS * PER_SUBMITTER);
    assert_eq!(total, submitted.load(Ordering::Relaxed));
    let m = srv.shutdown();
    assert_eq!(
        m.completed(),
        total,
        "aggregate metrics agree: one completion per submission"
    );
    assert_eq!(m.arrivals(), total as u64);
    assert_eq!(
        srv.lane_load().iter().sum::<u64>(),
        0,
        "no request left accounted outstanding"
    );
}

/// Retirement under a deep backlog stays hitless: everything queued
/// before the cut-over is served by the draining lane, everything after
/// lands on the replacement.
#[test]
fn deep_backlog_drains_across_handoff() {
    let srv = Arc::new(Server::start_plan(
        vec![{
            let mut l = lane("m", Duration::from_millis(2), 1.0);
            l.batcher.max_batch = 1;
            l
        }],
        ServerConfig::default(),
    ));
    let d = Duration::from_secs(30);
    let before: Vec<_> = (0..40)
        .map(|i| srv.submit_to("m", vec![i as f32, 0.0, 0.0, 0.0], d).unwrap())
        .collect();
    // Replacement up, old one draining (non-blocking retire).
    let fresh = srv.add_lane(lane("m", Duration::from_micros(100), 2.0));
    srv.begin_retire(0).unwrap();
    let after: Vec<_> = (0..40)
        .map(|i| srv.submit_to("m", vec![i as f32, 0.0, 0.0, 0.0], d).unwrap())
        .collect();
    for (i, rx) in before.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("drained request lost");
        assert_eq!(r.logits[0], i as f32);
        assert_eq!(r.logits[1], 1.0, "pre-cut-over requests served by the OLD lane");
    }
    for (i, rx) in after.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("rerouted request lost");
        assert_eq!(r.logits[0], i as f32);
        assert_eq!(r.logits[1], 2.0, "post-cut-over requests served by the NEW lane");
    }
    // The drained lane reaps cleanly.
    let t0 = Instant::now();
    while !srv.finish_retire(0) {
        assert!(t0.elapsed() < Duration::from_secs(5));
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(srv.live_lanes().len(), 1);
    assert_eq!(srv.live_lanes()[0].0, fresh);
    srv.shutdown();
}
