//! Replica-plan invariants (PR 4):
//!
//! 1. **Disjoint tiling** (property): however the planner splits a model
//!    into R replicas, the replica tori tile disjoint contiguous board
//!    sub-ranges inside the model's allocation, and model allocations
//!    tile the fleet.
//! 2. **Replica-count drift is minimal** (`diff_plans` R → R+1 produces
//!    exactly one added lane and zero retires — covered at the unit level
//!    in `control::replanner`, re-checked here through real planner
//!    output end-to-end).
//! 3. **Exactly-one-response across a replica-count migration**: the
//!    `tests/control_migration.rs` invariant holds while a model's
//!    replica lane set grows and shrinks under concurrent submitters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;
use superlip::control::diff_plans;
use superlip::fleet::{FleetSpec, Planner, PlannerConfig, ReplicaPolicy, WorkloadSpec};
use superlip::platform::FpgaSpec;
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, Server, ServerConfig,
};
use superlip::util::{proptest::forall, SplitMix64};

fn w(model: &str, rate: f64, deadline_ms: f64) -> WorkloadSpec {
    WorkloadSpec::new(model, rate, Duration::from_secs_f64(deadline_ms / 1e3))
}

/// Property: replicas tile disjoint torus sub-grids, whatever the mix.
#[test]
fn replicas_tile_disjoint_subgrids() {
    const FLEET: usize = 8;
    // ONE planner: its sub-plan cache makes the 60 random cases cheap.
    // The energy pass is disabled so the "R is maximal for the chosen k"
    // invariant below holds exactly (with energy on, Auto may deliberately
    // under-fill an allocation and leave a larger power-down remainder —
    // that shape is property-tested in tests/power_props.rs).
    let planner = Planner::new(
        FleetSpec::homogeneous(FLEET, FpgaSpec::zcu102()),
        PlannerConfig {
            energy_tolerance: -1.0,
            ..PlannerConfig::default()
        },
    );
    let s1 = planner.service_ms("alexnet", 1).unwrap();
    let q1 = planner.service_ms("squeezenet", 1).unwrap();

    #[derive(Debug, Clone)]
    struct Case {
        split: usize, // boards for model 0 (1..FLEET-1)
        rate_pct: [u64; 2],
        dl_mult: [u64; 2],
        policy: [u64; 2], // 0 = auto, r = Fixed(r)
    }

    forall(
        0x5EED_2026,
        60,
        |r: &mut SplitMix64| Case {
            split: r.range(1, (FLEET - 1) as u64) as usize,
            rate_pct: [r.range(5, 120), r.range(5, 120)],
            dl_mult: [r.range(1, 40), r.range(1, 40)],
            policy: [r.range(0, 3), r.range(0, 3)],
        },
        |c: &Case| {
            let counts = vec![c.split, FLEET - c.split];
            let mk = |model: &str, svc1: f64, i: usize| {
                let mut spec = w(
                    model,
                    c.rate_pct[i] as f64 / 100.0 / (svc1 / 1e3),
                    c.dl_mult[i] as f64 * svc1,
                );
                if c.policy[i] > 0 {
                    // A pinned count larger than the allocation is a
                    // legitimate planner error, not a tiling violation —
                    // clamp into range.
                    spec = spec.with_replicas((c.policy[i] as usize).min(counts[i]));
                }
                spec
            };
            let mix = vec![mk("alexnet", s1, 0), mk("squeezenet", q1, 1)];
            let plan = match planner.plan_allocation(&mix, &counts) {
                Ok(p) => p,
                Err(_) => return false,
            };
            // Model allocations tile the fleet in mix order.
            if plan.allocation() != counts {
                return false;
            }
            let mut model_start = 0usize;
            for (mi, m) in mix.iter().enumerate() {
                let reps: Vec<_> = plan.model_deployments(&m.model).collect();
                if reps.is_empty() {
                    return false;
                }
                let r_count = reps.len();
                if let ReplicaPolicy::Fixed(r) = m.replicas {
                    if r_count != r {
                        return false;
                    }
                }
                let k = reps[0].n_boards;
                for (ri, d) in reps.iter().enumerate() {
                    let ok = d.replica == ri
                        && d.n_replicas == r_count
                        && d.model_boards == counts[mi]
                        && d.n_boards == k
                        && d.start == model_start + ri * k
                        && d.start + d.n_boards <= model_start + counts[mi]
                        && d.torus.0 * d.torus.1 == d.n_boards as u64
                        && (d.share_rate_rps * r_count as f64 - m.rate_rps).abs()
                            < 1e-9 * m.rate_rps;
                    if !ok {
                        return false;
                    }
                }
                // Replicas fit inside the allocation; under Auto, R is
                // maximal for the chosen k (a further size-k replica would
                // not fit — Fixed pins R, so its remainder may be larger).
                if r_count * k > counts[mi] {
                    return false;
                }
                if m.replicas == ReplicaPolicy::Auto && counts[mi] - r_count * k >= k {
                    return false;
                }
                model_start += counts[mi];
            }
            model_start == FLEET
        },
    );
}

/// R → R+1 drift through REAL planner output is exactly one added lane.
#[test]
fn replica_growth_is_one_added_lane() {
    let mk_plan = |boards: usize, reps: usize| {
        let planner = Planner::new(
            FleetSpec::homogeneous(boards, FpgaSpec::zcu102()),
            PlannerConfig::default(),
        );
        let mix = vec![w("alexnet", 60.0, 80.0).with_replicas(reps)];
        planner.plan_allocation(&mix, &[boards]).unwrap()
    };
    // 2×2 boards → 3×2 boards: same per-replica shape, one more lane.
    let two = mk_plan(4, 2);
    let three = mk_plan(6, 3);
    let d = diff_plans(&two, &three);
    assert_eq!(d.keep.len(), 2, "{d:?}");
    assert_eq!(d.add.len(), 1, "{d:?}");
    assert_eq!(d.retire.len(), 0, "{d:?}");
    // The added index is the third replica of the hot model.
    assert_eq!(three.deployments[d.add[0]].replica, 2);
}

/// Deterministic stub backend: logits[0] = sum(image), logits[1] = lane tag.
struct Stub {
    delay: Duration,
    tag: f32,
}

impl InferBackend for Stub {
    fn image_elems(&self) -> usize {
        4
    }
    fn classes(&self) -> usize {
        2
    }
    fn max_batch(&self) -> usize {
        2
    }
    fn infer(&self, images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        let mut out = Vec::with_capacity(n * 2);
        for i in 0..n {
            out.push(images[i * 4..(i + 1) * 4].iter().sum());
            out.push(self.tag);
        }
        Ok(out)
    }
}

fn lane(tag: f32) -> LaneSpec {
    LaneSpec {
        model: "m".into(),
        factories: vec![Box::new(move || {
            Ok(Box::new(Stub {
                delay: Duration::from_micros(500),
                tag,
            }) as Box<dyn InferBackend>)
        }) as BackendFactory],
        batcher: BatcherConfig {
            max_batch: 2,
            window: Duration::from_micros(300),
            deadline_margin: Duration::from_micros(300),
            ..BatcherConfig::default()
        },
    }
}

/// The control-migration invariant across replica-COUNT migrations: while
/// 3 submitters fire continuously, the model's replica lane set grows
/// 2 → 3 and shrinks 3 → 2 repeatedly; every accepted request gets
/// exactly one response and the books balance.
#[test]
fn exactly_one_response_across_replica_count_migrations() {
    const SUBMITTERS: usize = 3;
    const PER_SUBMITTER: usize = 100;
    const ROUNDS: usize = 8;

    let srv = Arc::new(Server::start_plan(
        vec![lane(0.0), lane(1.0)],
        ServerConfig::default(),
    ));
    let refused = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::new();
    for sid in 0..SUBMITTERS {
        let srv = srv.clone();
        let refused = refused.clone();
        handles.push(std::thread::spawn(move || {
            let mut responses = Vec::new();
            for i in 0..PER_SUBMITTER {
                let v = (sid * PER_SUBMITTER + i) as f32;
                match srv.submit_to("m", vec![v, 0.0, 0.0, 0.0], Duration::from_secs(30)) {
                    Ok(rx) => responses.push((v, rx)),
                    Err(_) => {
                        refused.fetch_add(1, Ordering::Relaxed);
                    }
                }
                std::thread::sleep(Duration::from_micros(150));
            }
            let mut got = 0usize;
            for (v, rx) in responses {
                let r = rx
                    .recv_timeout(Duration::from_secs(20))
                    .unwrap_or_else(|e| panic!("request {v} lost in replica migration: {e}"));
                assert_eq!(r.logits[0], v, "response landed on the wrong request");
                assert!(
                    rx.try_recv().is_err(),
                    "request {v} answered more than once"
                );
                got += 1;
            }
            got
        }));
    }

    // Grow to 3 replicas, then shrink back to 2, repeatedly — always
    // make-before-break (the shrink only retires once 3 lanes serve).
    let migrator = {
        let srv = srv.clone();
        std::thread::spawn(move || {
            let mut live = vec![0usize, 1usize];
            for round in 0..ROUNDS {
                let fresh = srv.add_lane(lane((round + 2) as f32));
                live.push(fresh);
                std::thread::sleep(Duration::from_millis(4));
                // Shrink: retire the OLDEST replica lane (blocking drain —
                // everything it queued is still served).
                let victim = live.remove(0);
                srv.retire_lane(victim).expect("victim lane was live");
                std::thread::sleep(Duration::from_millis(4));
            }
            live
        })
    };

    let mut total = 0usize;
    for h in handles {
        total += h.join().expect("submitter panicked");
    }
    let live = migrator.join().expect("migrator panicked");
    assert_eq!(live.len(), 2, "net replica count restored");
    assert_eq!(refused.load(Ordering::Relaxed), 0, "make-before-break never refuses");
    assert_eq!(total, SUBMITTERS * PER_SUBMITTER);
    let m = srv.shutdown();
    assert_eq!(m.completed(), total, "one completion per submission");
    assert_eq!(m.arrivals(), total as u64);
    assert_eq!(
        srv.lane_load().iter().sum::<u64>(),
        0,
        "no request left accounted outstanding"
    );
}
