//! Property tests for incremental re-planning (`control::Replanner`):
//!
//! 1. **Bit-identity** — over randomized drift sequences, every
//!    incremental plan equals the from-scratch arithmetic on the same
//!    allocation and effective mix, byte for byte (f64 `Debug` round-trips,
//!    so equal debug strings ⇔ equal bits).
//! 2. **Zero churn for clean models** — a model the tolerance band calls
//!    clean never appears in `diff_plans` retire/add sets.
//! 3. **Invalidation** — fleet shrink (board death) and precision-degrade
//!    swaps (the controller's `invalidate_plan` hook) force the next
//!    re-plan through the full composition search.
//! 4. **O(dirty) re-scoring** — on a 50-model fleet, a single-model drift
//!    re-scores exactly that model; everything else is pure cache reads.

use std::time::Duration;
use superlip::control::{diff_plans, Replanner};
use superlip::fleet::{FleetSpec, Planner, PlannerConfig, WorkloadSpec};
use superlip::platform::FpgaSpec;
use superlip::util::SplitMix64;

fn fleet(n: usize) -> FleetSpec {
    FleetSpec::homogeneous(n, FpgaSpec::zcu102())
}

fn w(model: &str, rate: f64, deadline_ms: f64) -> WorkloadSpec {
    WorkloadSpec::new(model, rate, Duration::from_secs_f64(deadline_ms / 1e3))
}

fn dbg_plan(p: &superlip::fleet::FleetPlan) -> String {
    format!("{p:?}")
}

#[test]
fn random_drift_sequences_are_bit_identical_to_scratch() {
    for seed in [11u64, 23, 47, 91] {
        let mut rng = SplitMix64::new(seed);
        let mut rp = Replanner::new(fleet(4), PlannerConfig::default());
        // A COLD planner per comparison would re-derive everything; one
        // warm scratch planner is fine — caching must not change results,
        // which is exactly the property under test.
        let scratch = Planner::new(fleet(4), PlannerConfig::default());
        let base = vec![
            w("alexnet", 40.0, 120.0),
            w("squeezenet", 60.0, 120.0),
            w("yolo", 1.0, 800.0),
        ];
        let mut rates: Vec<f64> = base.iter().map(|x| x.rate_rps).collect();
        let mut prev = rp.plan_incremental(&base, &[false; 3]).unwrap();
        assert!(!prev.incremental, "first call has no plan memory");
        for round in 0..8 {
            let mut observed = base.clone();
            let mut moved = vec![false; 3];
            for i in 0..3 {
                if rng.below(2) == 0 {
                    moved[i] = true;
                    // Multiplier in [0.5, 2.0) of the base rate.
                    let f = 0.5 + rng.below(1500) as f64 / 1000.0;
                    rates[i] = base[i].rate_rps * f;
                }
                observed[i].rate_rps = rates[i];
            }
            let out = rp.plan_incremental(&observed, &moved).unwrap();
            let ctx = format!("seed={seed} round={round} moved={moved:?}");
            if out.incremental {
                // Bit-identity: the reused-allocation arithmetic, from
                // scratch, on the effective mix.
                let sp = scratch
                    .plan_allocation(&out.mix, &out.plan.allocation())
                    .unwrap();
                assert_eq!(dbg_plan(&out.plan), dbg_plan(&sp), "{ctx}");
                // Zero churn for clean models.
                let delta = diff_plans(&prev.plan, &out.plan);
                for clean in &out.reused {
                    assert!(
                        !delta.retire.iter().any(|m| m == clean),
                        "{ctx}: clean `{clean}` retired: {delta:?}"
                    );
                    assert!(
                        !delta
                            .add
                            .iter()
                            .any(|&i| out.plan.deployments[i].workload.model == *clean),
                        "{ctx}: clean `{clean}` re-added: {delta:?}"
                    );
                }
            } else {
                // Fallback rounds equal the full search, bit for bit.
                let sp = scratch.plan(&out.mix).unwrap();
                assert_eq!(dbg_plan(&out.plan), dbg_plan(&sp), "{ctx}");
            }
            prev = out;
        }
    }
}

#[test]
fn shrink_and_degrade_invalidate_the_plan_memory() {
    let mut rp = Replanner::new(fleet(4), PlannerConfig::default());
    let mix = vec![w("alexnet", 20.0, 150.0), w("squeezenet", 20.0, 150.0)];
    rp.plan_incremental(&mix, &[false, false]).unwrap();
    let warm = rp.plan_incremental(&mix, &[false, false]).unwrap();
    assert!(warm.incremental);

    // Board death: the next re-plan must re-search on the survivors.
    rp.remove_board(3).unwrap();
    let post = rp.plan_incremental(&mix, &[false, false]).unwrap();
    assert!(!post.incremental, "shrink must invalidate the plan memory");
    assert_eq!(post.plan.allocation().iter().sum::<usize>(), 3);

    // Precision degrade (the controller swaps a lane down a rung, then
    // calls invalidate_plan): the next re-plan must not resurrect the
    // pre-degrade deployments.
    let again = rp.plan_incremental(&mix, &[false, false]).unwrap();
    assert!(again.incremental);
    let victim = again.plan.deployments[0].clone();
    if let Ok(deg) = rp.degraded_deployment(&victim) {
        assert_ne!(
            deg.design.precision, victim.design.precision,
            "degrade must change the precision rung"
        );
    }
    rp.invalidate_plan();
    let after = rp.plan_incremental(&mix, &[false, false]).unwrap();
    assert!(!after.incremental, "degrade swap must force a full search");
}

#[test]
fn fifty_model_single_drift_rescores_only_that_model() {
    // 50 variant-tagged models (`alexnet#NN`), one board each: the
    // simulated big-fleet shape. A single model drifting must re-score
    // exactly that model, with every other evaluation a pure cache read.
    const M: usize = 50;
    let planner = Planner::new(fleet(M), PlannerConfig::default());
    let s1 = planner.service_ms("alexnet", 1).unwrap();
    let rate = 0.3 / (s1 / 1e3);
    let deadline_ms = 20.0 * s1;
    let mix: Vec<WorkloadSpec> = (0..M)
        .map(|i| w(&format!("alexnet#{i:02}"), rate, deadline_ms))
        .collect();
    let mut rp = Replanner::new(fleet(M), PlannerConfig::default());
    let first = rp.plan_incremental(&mix, &[false; M]).unwrap();
    assert!(!first.incremental);
    assert_eq!(first.plan.allocation(), vec![1; M], "one board per model");
    assert!(first.plan.worst_risk.is_finite());

    // Idle round: everything reused, zero evaluations.
    rp.reset_cache_stats();
    let idle = rp.plan_incremental(&mix, &[false; M]).unwrap();
    assert!(idle.incremental);
    assert_eq!(idle.reused.len(), M);
    let st = rp.cache_stats();
    assert_eq!((st.split_misses, st.subplan_misses), (0, 0), "{st:?}");

    // Single-model drift: only alexnet#07 re-scores.
    let mut drifted = mix.clone();
    drifted[7].rate_rps *= 1.8;
    let mut moved = vec![false; M];
    moved[7] = true;
    rp.reset_cache_stats();
    let out = rp.plan_incremental(&drifted, &moved).unwrap();
    assert!(out.incremental);
    assert_eq!(out.rescored, vec!["alexnet#07"]);
    assert_eq!(out.reused.len(), M - 1);
    let st = rp.cache_stats();
    assert_eq!(st.subplan_misses, 0, "sub-plan layer fully warm: {st:?}");
    assert!(
        st.split_misses <= 1,
        "at most the drifted model's new rate misses the split memo: {st:?}"
    );
    assert!(st.hit_rate() >= 0.5, "{st:?}");

    // The 49 clean models' deployments are byte-identical and diff to
    // zero churn.
    let delta = diff_plans(&first.plan, &out.plan);
    for (i, m) in mix.iter().enumerate() {
        if i == 7 {
            continue;
        }
        let old: Vec<String> = first
            .plan
            .model_deployments(&m.model)
            .map(|d| format!("{d:?}"))
            .collect();
        let new: Vec<String> = out
            .plan
            .model_deployments(&m.model)
            .map(|d| format!("{d:?}"))
            .collect();
        assert_eq!(old, new, "clean `{}` must be reused byte-for-byte", m.model);
        assert!(!delta.retire.iter().any(|r| r == &m.model));
    }

    // Bit-identity of the whole incremental plan against from-scratch
    // arithmetic on the same allocation and effective mix.
    let scratch = Planner::new(fleet(M), PlannerConfig::default());
    let sp = scratch.plan_allocation(&out.mix, &out.plan.allocation()).unwrap();
    assert_eq!(dbg_plan(&out.plan), dbg_plan(&sp));
}
