//! Fast-path ⇔ reference equivalence (the §Perf contract): the
//! closed-form worst-slice evaluation, the folded adaptive-XFER
//! comparison, the layer-shape dedup and the parallel branch-and-bound
//! searches must all return results **bit-identical** to the retained
//! naive implementations, across random layers, designs, factors and
//! modes.

use superlip::analytic::{
    layer_latency, xfer_layer_latency, xfer_layer_latency_ref, xfer_network_latency,
    xfer_network_latency_ref, Design, XferMode,
};
use superlip::dse;
use superlip::model::{ConvLayer, Network};
use superlip::partition::Factors;
use superlip::platform::{FpgaSpec, Precision};
use superlip::util::proptest::forall;
use superlip::util::SplitMix64;

/// Random conv layer in realistic ranges (awkward remainders included).
fn gen_layer(r: &mut SplitMix64) -> ConvLayer {
    let k = *r.choose(&[1u64, 3, 5, 7, 11]);
    let mut l = ConvLayer::strided(
        "prop",
        r.range(1, 4),
        r.range(1, 512),
        r.range(1, 512),
        r.range(1, 56),
        r.range(1, 56),
        k,
        r.range(1, 2),
    );
    // Occasionally grouped (AlexNet conv2/4/5 style), when divisible.
    if r.below(4) == 0 && l.m % 2 == 0 && l.n % 2 == 0 {
        l = l.grouped(2);
    }
    l
}

fn gen_design(r: &mut SplitMix64) -> Design {
    let p = if r.below(2) == 0 {
        Precision::Float32
    } else {
        Precision::Fixed16
    };
    Design {
        tm: r.range(1, 128),
        tn: r.range(1, 64),
        tr: r.range(1, 14),
        tc: r.range(1, 14),
        ip: *r.choose(&[1u64, 2, 4, 8]),
        wp: *r.choose(&[1u64, 2, 4, 8]),
        op: *r.choose(&[1u64, 2, 4, 8]),
        precision: p,
    }
}

fn gen_factors(r: &mut SplitMix64) -> Factors {
    Factors::new(
        *r.choose(&[1u64, 2]),
        *r.choose(&[1u64, 2, 3, 4]),
        *r.choose(&[1u64, 2, 3]),
        *r.choose(&[1u64, 2, 3, 4]),
    )
}

fn gen_mode(r: &mut SplitMix64) -> XferMode {
    if r.below(2) == 0 {
        XferMode::Baseline
    } else {
        XferMode::Xfer
    }
}

#[test]
fn prop_closed_form_equals_naive_reference() {
    let fpga = FpgaSpec::zcu102();
    forall(
        0xE901,
        500,
        |r| (gen_layer(r), gen_design(r), gen_factors(r), gen_mode(r)),
        |(l, d, f, mode)| {
            let fast = xfer_layer_latency(l, d, f, &fpga, *mode);
            let slow = xfer_layer_latency_ref(l, d, f, &fpga, *mode);
            fast == slow
        },
    );
}

#[test]
fn prop_network_dedup_equals_naive_sum() {
    let fpga = FpgaSpec::zcu102();
    forall(
        0xDED0,
        120,
        |r| {
            // Random small net WITH forced shape repeats (the dedup path).
            let a = gen_layer(r);
            let b = gen_layer(r);
            let layers = vec![a.clone(), b.clone(), a.clone(), b, a];
            (Network::new("prop", layers), gen_design(r), gen_factors(r), gen_mode(r))
        },
        |(net, d, f, mode)| {
            xfer_network_latency(net, d, f, &fpga, *mode)
                == xfer_network_latency_ref(net, d, f, &fpga, *mode)
        },
    );
}

#[test]
fn vgg16_dedup_cache_correct() {
    // VGG16's stacked 3×3 blocks are the motivating dedup case: the class
    // list must be strictly smaller than the layer list, multiplicities
    // must cover every conv layer, and the dedup'd sums must equal the
    // naive per-layer sums exactly.
    let net = superlip::model::zoo::vgg16();
    let classes = net.conv_shape_classes();
    let n_layers = net.conv_layers().count() as u64;
    assert!(
        (classes.len() as u64) < n_layers,
        "VGG16 must have repeated conv shapes: {} classes vs {} layers",
        classes.len(),
        n_layers
    );
    assert_eq!(classes.iter().map(|&(_, c)| c).sum::<u64>(), n_layers);

    let fpga = FpgaSpec::zcu102();
    let d = Design::fixed16(64, 26, 14, 14);
    // Single-FPGA sum (network_latency dedups internally).
    let by_layer: u64 = net.conv_layers().map(|l| layer_latency(l, &d).lat).sum();
    assert_eq!(superlip::analytic::network_latency(&net, &d), by_layer);
    // Cluster sums across several schemes and both modes.
    for f in [
        Factors::single(),
        Factors::new(1, 2, 1, 1),
        Factors::new(1, 2, 1, 2),
        Factors::new(1, 4, 1, 4),
    ] {
        for mode in [XferMode::Baseline, XferMode::Xfer] {
            assert_eq!(
                xfer_network_latency(&net, &d, &f, &fpga, mode),
                xfer_network_latency_ref(&net, &d, &f, &fpga, mode),
                "{f} {mode:?}"
            );
        }
    }
}

#[test]
fn best_factors_equals_naive_enumeration() {
    // The parallel single-pass search must pick exactly the scheme the
    // seed's two-pass sequential scan picked: first (in enumeration order)
    // among the admissible minima.
    let fpga = FpgaSpec::zcu102();
    for (net, d, sizes) in [
        (
            superlip::model::zoo::alexnet(),
            Design::fixed16(128, 10, 7, 14),
            vec![2u64, 4, 8],
        ),
        (
            superlip::model::zoo::yolov1(),
            Design::fixed16(64, 25, 7, 14),
            vec![16u64],
        ),
    ] {
        for &n in &sizes {
            for mode in [XferMode::Baseline, XferMode::Xfer] {
                let max_b = net.layers.first().map(|l| l.b).unwrap_or(1);
                let mut naive: Option<(Factors, u64)> = None;
                for f in Factors::enumerate(n, max_b) {
                    if mode == XferMode::Xfer {
                        let ok = net.conv_layers().all(|l| {
                            xfer_layer_latency_ref(l, &d, &f, &fpga, mode).bandwidth_ok
                        });
                        if !ok {
                            continue;
                        }
                    }
                    let cycles = xfer_network_latency_ref(&net, &d, &f, &fpga, mode);
                    if naive.as_ref().map(|&(_, b)| cycles < b).unwrap_or(true) {
                        naive = Some((f, cycles));
                    }
                }
                let fast = dse::best_factors(&net, &d, &fpga, n, mode);
                assert_eq!(fast, naive.unwrap(), "{} n={n} {mode:?}", net.name);
            }
        }
    }
}

#[test]
fn cross_layer_search_equals_bruteforce_on_toy_net() {
    // A layer small enough to brute-force the whole candidate space with
    // no pruning: the parallel branch-and-bound top-1 must match the
    // global minimum (ties to the earliest candidate in nest order).
    use superlip::analytic::is_feasible;
    use superlip::dse::{candidate_tiles, stream_presets};

    let net = Network::new("toy", vec![ConvLayer::conv("t", 1, 8, 8, 6, 6, 3)]);
    let fpga = FpgaSpec::zcu102();
    let p = Precision::Fixed16;
    let layer = &net.layers[0];

    let desc = |mut v: Vec<u64>| {
        v.reverse();
        v
    };
    let tm_c = desc(candidate_tiles(layer.m_per_group()));
    let tn_c = desc(candidate_tiles(layer.n_per_group()));
    let tr_c = desc(candidate_tiles(layer.r));
    let tc_c = desc(candidate_tiles(layer.c));
    let k_max = layer.k;
    let mut brute: Option<(Design, u64)> = None;
    for &tm in &tm_c {
        for &tn in &tn_c {
            if tm * tn > fpga.max_macs(p) {
                continue;
            }
            for &tr in &tr_c {
                for &tc in &tc_c {
                    for &(ip, wp, op) in &stream_presets(p, &fpga) {
                        let d = Design {
                            tm,
                            tn,
                            tr,
                            tc,
                            ip,
                            wp,
                            op,
                            precision: p,
                        };
                        if !is_feasible(&d, &fpga, k_max) {
                            continue;
                        }
                        let cycles = layer_latency(layer, &d).lat;
                        if brute.as_ref().map(|&(_, b)| cycles < b).unwrap_or(true) {
                            brute = Some((d, cycles));
                        }
                    }
                }
            }
        }
    }
    let (top, _, _) = dse::top_uniform_designs(&net, &fpga, p, 1);
    assert_eq!(top[0], brute.unwrap());
}
