//! Power-manager properties (ISSUE 5):
//!
//! 1. **State machine** (randomized): wake latency is always respected —
//!    a board is never usable between `power_down` and its wake deadline;
//!    an `Active` board can never be powered down; `serve_check` trips
//!    (and counts) exactly on non-Active boards.
//! 2. **No request is ever routed to a non-Active board** and **every
//!    request gets exactly one response across a consolidation
//!    migration**: a hot→cool→hot scenario drives the controller through
//!    a consolidation power-down AND a wake-before-route re-expansion
//!    under live traffic; the serve gate must count zero violations and
//!    every submitted request must complete exactly once.
//! 3. **Energy-aware plans tile the fleet**: with the energy pass on,
//!    partial replica fills still tile disjoint sub-ranges and the
//!    power-down candidates are exactly the unused boards.

use std::time::Duration;
use superlip::control::{run_drift_scenario, OnlineConfig, PowerGating};
use superlip::fleet::{FleetSpec, PhaseSpec, Planner, PlannerConfig, WorkloadSpec};
use superlip::platform::FpgaSpec;
use superlip::power::{FleetPower, PowerState};
use superlip::util::{proptest::forall, SplitMix64};

/// Reference model for one board, mirrored against the real machine.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Ref {
    Active,
    Idle,
    Off,
    Waking(u64), // wake deadline in ticks
}

#[test]
fn state_machine_respects_wake_latency_and_transitions() {
    const WAKE: f64 = 5.0; // model seconds (integer ticks keep refs exact)

    #[derive(Debug, Clone)]
    struct Case {
        ops: Vec<(u64, u64)>, // (op, board)
    }

    forall(
        0x50_57A7E_2026,
        40,
        |r: &mut SplitMix64| Case {
            ops: (0..60).map(|_| (r.range(0, 5), r.range(0, 2))).collect(),
        },
        |c: &Case| {
            let p = FleetPower::new(3, WAKE, 1.0);
            let mut refs = [Ref::Idle; 3];
            let mut violations = 0u64;
            for (t, &(op, board)) in c.ops.iter().enumerate() {
                let now = t as f64;
                let b = board as usize;
                // Resolve the reference's pending wake first, like the
                // machine does lazily.
                if let Ref::Waking(until) = refs[b] {
                    if t as u64 >= until {
                        refs[b] = Ref::Idle;
                    }
                }
                match op {
                    0 => {
                        let ok = p.set_active_at(b, now).is_ok();
                        let want = matches!(refs[b], Ref::Active | Ref::Idle);
                        if ok != want {
                            return false;
                        }
                        if want {
                            refs[b] = Ref::Active;
                        }
                    }
                    1 => {
                        let ok = p.set_idle_at(b, now).is_ok();
                        let want = matches!(refs[b], Ref::Active | Ref::Idle);
                        if ok != want {
                            return false;
                        }
                        if want {
                            refs[b] = Ref::Idle;
                        }
                    }
                    2 => {
                        let ok = p.power_down_at(b, now).is_ok();
                        // Only an Active board refuses (its lane must
                        // retire first); Waking aborts to Off.
                        let want = !matches!(refs[b], Ref::Active);
                        if ok != want {
                            return false;
                        }
                        if want {
                            refs[b] = Ref::Off;
                        }
                    }
                    3 => {
                        let ready = p.begin_wake_at(b, now);
                        match refs[b] {
                            Ref::Off => {
                                if (ready - (now + WAKE)).abs() > 1e-9 {
                                    return false;
                                }
                                refs[b] = Ref::Waking(t as u64 + WAKE as u64);
                            }
                            Ref::Waking(until) => {
                                if (ready - until as f64).abs() > 1e-9 {
                                    return false;
                                }
                            }
                            _ => {
                                if (ready - now).abs() > 1e-9 {
                                    return false;
                                }
                            }
                        }
                    }
                    _ => {
                        // Serve gate: must pass iff Active, and count a
                        // violation otherwise.
                        let before = p.violations();
                        let ok = p.serve_check(b);
                        let want = refs[b] == Ref::Active;
                        if ok != want {
                            return false;
                        }
                        if p.violations() != before + u64::from(!want) {
                            return false;
                        }
                        violations += u64::from(!want);
                    }
                }
                // Invariants, every step: state agrees with the
                // reference; a waking board is unusable before its
                // deadline.
                let state = p.state_at(b, now);
                let want_state = match refs[b] {
                    Ref::Active => PowerState::Active,
                    Ref::Idle => PowerState::Idle,
                    Ref::Off => PowerState::PoweredOff,
                    Ref::Waking(until) => {
                        if (t as u64) < until {
                            PowerState::Waking
                        } else {
                            PowerState::Idle
                        }
                    }
                };
                if state != want_state {
                    return false;
                }
                if state == PowerState::Waking && p.is_usable_at(b, now) {
                    return false;
                }
            }
            p.violations() == violations
        },
    );
}

/// End-to-end: consolidation powers boards down, the re-warm wakes one
/// BEFORE routing — zero serve-gate violations, exactly one response per
/// request throughout, and the freed board really is off in between.
#[test]
fn consolidation_routes_only_to_active_boards_with_exactly_one_response() {
    let fleet = FleetSpec::homogeneous(3, FpgaSpec::zcu102());
    let pcfg = PlannerConfig::default();
    let planner = Planner::new(fleet.clone(), pcfg);
    let a1 = planner.service_ms("alexnet", 1).unwrap() / 1e3;
    let a2 = planner.service_ms("alexnet", 2).unwrap() / 1e3;
    let q1 = planner.service_ms("squeezenet", 1).unwrap() / 1e3;
    // Hot alexnet saturates one board (needs its 2-board torus); cold
    // squeezenet idles on one. The cool phase collapses alexnet to a
    // trickle → the controller consolidates to 1 board each and powers
    // the freed board down; the re-warm needs it back.
    let hot = 0.5 / a2;
    let mix = vec![
        WorkloadSpec::new("alexnet", hot, Duration::from_secs_f64(6.0 * a1)),
        WorkloadSpec::new("squeezenet", 0.25 / q1, Duration::from_secs_f64(6.0 * q1)),
    ];
    let phases = vec![
        PhaseSpec {
            duration_s: 0.5,
            rates_rps: vec![hot, 0.25 / q1],
        },
        PhaseSpec {
            duration_s: 0.8,
            rates_rps: vec![0.05 / a1, 0.25 / q1],
        },
        PhaseSpec {
            duration_s: 0.6,
            rates_rps: vec![hot, 0.25 / q1],
        },
    ];
    let cfg = OnlineConfig {
        seed: 7,
        time_scale: 0.5,
        tick_s: 0.1,
        power: Some(PowerGating { wake_latency_s: 0.1 }),
        recv_timeout: Duration::from_secs(30),
        ..OnlineConfig::default()
    };
    let out = run_drift_scenario(&fleet, pcfg, &mix, &phases, &cfg, true).unwrap();

    // The consolidation happened and the re-warm woke a board.
    assert!(out.replans >= 1, "cool-off must re-plan: {:?}", out.events);
    assert!(
        out.events.iter().any(|e| e.contains("powered down boards")),
        "freed boards must power down: {:?}",
        out.events
    );
    assert!(
        out.events.iter().any(|e| e.contains("waking boards")),
        "the re-warm must wake before routing: {:?}",
        out.events
    );
    // Headline property 1: the serve gate never saw a non-Active board.
    assert_eq!(
        out.power_violations, 0,
        "no request may ever be routed to a non-Active board: {:?}",
        out.events
    );
    // Headline property 2: exactly one response per submitted request —
    // nothing was killed, so sent == completed in every phase row.
    for rows in &out.phase_stats {
        for r in rows {
            assert_eq!(
                r.completed, r.sent,
                "{}: exactly-one-response across consolidation ({:?})",
                r.model, out.events
            );
        }
    }
    // Watts actually dropped during the cool phase.
    assert!(
        out.avg_watts[1] < out.avg_watts[0],
        "cool phase must draw less: {:?}",
        out.avg_watts
    );
}

/// Energy-aware plans still tile the fleet: partial replica fills leave
/// their remainder as power-down candidates, disjoint from every torus.
#[test]
fn energy_plans_tile_and_list_candidates() {
    let planner = Planner::new(
        FleetSpec::homogeneous(6, FpgaSpec::zcu102()),
        PlannerConfig::default(),
    );
    let a1 = planner.service_ms("alexnet", 1).unwrap() / 1e3;
    let q1 = planner.service_ms("squeezenet", 1).unwrap() / 1e3;
    // Light loads: the energy pass serves each model from far fewer
    // boards than the composition hands it.
    let mix = vec![
        WorkloadSpec::new("alexnet", 0.2 / a1, Duration::from_secs_f64(8.0 * a1)),
        WorkloadSpec::new("squeezenet", 0.2 / q1, Duration::from_secs_f64(8.0 * q1)),
    ];
    let plan = planner.plan(&mix).unwrap();
    assert_eq!(plan.allocation().iter().sum::<usize>(), 6, "{}", plan.summary());
    let candidates = plan.power_down_candidates();
    let mut used: Vec<usize> = plan
        .deployments
        .iter()
        .flat_map(|d| d.start..d.start + d.n_boards)
        .collect();
    used.sort_unstable();
    used.dedup();
    assert_eq!(used.len() + candidates.len(), 6, "tori + candidates tile the fleet");
    assert!(
        used.iter().all(|b| !candidates.contains(b)),
        "candidates are disjoint from every torus: used {used:?} vs {candidates:?}"
    );
    // Light load ⇒ real consolidation potential surfaced.
    assert!(
        !candidates.is_empty(),
        "light mix must expose power-down candidates:\n{}",
        plan.summary()
    );
    // Watts books balance.
    let total: f64 = plan.deployments.iter().map(|d| d.watts).sum();
    assert!((plan.active_watts() - total).abs() < 1e-9);
    assert!(plan.ungated_watts() >= plan.active_watts());
}
