//! Golden tolerance: pin `sim::engine` against the paper's analytic model
//! on the Figure 14 design points (AlexNet, float32 ⟨12,16⟩ / ⟨10,22⟩ /
//! ⟨8,32⟩ with ⟨Tr,Tc⟩ = ⟨13,13⟩). The calibrated `SimConfig::zcu102`
//! claims the model tracks simulation within ~2.5% on these designs — any
//! simulator or model edit that silently drifts past that budget fails
//! here instead of quietly invalidating the Figure 14 reproduction.

use superlip::analytic::{layer_latency, network_latency, Design, XferMode};
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::FpgaSpec;
use superlip::sim::{simulate_layer, simulate_network, SimConfig};

const FIG14_POINTS: [(u64, u64); 3] = [(12, 16), (10, 22), (8, 32)];
/// The `SimConfig::zcu102` doc claim.
const TOLERANCE: f64 = 0.025;

fn setup() -> (FpgaSpec, SimConfig) {
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    (fpga, cfg)
}

#[test]
fn figure14_network_divergence_within_tolerance() {
    let (fpga, cfg) = setup();
    let net = zoo::alexnet();
    for (tm, tn) in FIG14_POINTS {
        let d = Design::float32(tm, tn, 13, 13);
        let model = network_latency(&net, &d);
        let sim = simulate_network(&net, &d, &Factors::single(), &fpga, &cfg, XferMode::Xfer)
            .cycles;
        let dev = (sim as f64 - model as f64).abs() / sim as f64;
        assert!(
            dev <= TOLERANCE,
            "⟨{tm},{tn}⟩: model {model} vs sim {sim} diverge {:.3}% > 2.5%",
            dev * 100.0
        );
        assert!(
            sim >= model,
            "⟨{tm},{tn}⟩: the simulator only ADDS real-world cost (sim {sim} < model {model})"
        );
    }
}

#[test]
fn figure14_per_layer_divergence_within_tolerance() {
    let (_, cfg) = setup();
    let net = zoo::alexnet();
    for (tm, tn) in FIG14_POINTS {
        let d = Design::float32(tm, tn, 13, 13);
        for l in net.conv_layers() {
            let model = layer_latency(l, &d).lat;
            let sim = simulate_layer(l, &d, &cfg).cycles;
            let dev = (sim as f64 - model as f64).abs() / sim as f64;
            assert!(
                dev <= TOLERANCE,
                "⟨{tm},{tn}⟩ {}: model {model} vs sim {sim} diverge {:.3}% > 2.5%",
                l.name,
                dev * 100.0
            );
        }
    }
}

#[test]
fn tolerance_is_a_property_of_the_calibration_not_the_pipeline() {
    // Zeroing the calibrated overheads must collapse the gap to exactly 0 —
    // i.e. the ≤2.5% divergence above comes from the modeled real-world
    // costs (sync, DDR burst setup), not from a structural mismatch between
    // the simulator's pipeline walk and eqs 8–14.
    let net = zoo::alexnet();
    let ideal = SimConfig {
        sync_cycles: 0,
        ddr_tile_setup: 0,
        ddr_words_per_cycle: u64::MAX,
        link_setup: 0,
    };
    for (tm, tn) in FIG14_POINTS {
        let d = Design::float32(tm, tn, 13, 13);
        for l in net.conv_layers() {
            assert_eq!(
                simulate_layer(l, &d, &ideal).cycles,
                layer_latency(l, &d).lat,
                "⟨{tm},{tn}⟩ {}: ideal sim must equal the model exactly",
                l.name
            );
        }
    }
}
