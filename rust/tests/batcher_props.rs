//! Property tests for the deadline-aware batcher (`serving::Batcher`),
//! driven by the in-crate PRNG/property harness (`util::prng`,
//! `util::proptest`): batches never exceed `max_batch`, requests pop in
//! earliest-deadline-first order, a batch closes early once the earliest
//! deadline is within `deadline_margin`, and no request is ever dropped —
//! including under random concurrent arrival bursts.

use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};
use superlip::fleet::SloClass;
use superlip::serving::{Batcher, BatcherConfig, InferenceRequest, InferenceResponse};
use superlip::util::proptest::forall;
use superlip::util::SplitMix64;

fn req(
    id: u64,
    now: Instant,
    deadline_ms: u64,
) -> (InferenceRequest, mpsc::Receiver<InferenceResponse>) {
    req_class(id, now, deadline_ms, SloClass::BestEffort)
}

fn req_class(
    id: u64,
    now: Instant,
    deadline_ms: u64,
    class: SloClass,
) -> (InferenceRequest, mpsc::Receiver<InferenceResponse>) {
    let (tx, rx) = mpsc::channel();
    (
        InferenceRequest {
            id,
            image: Vec::new(),
            enqueued: now,
            deadline: now + Duration::from_millis(deadline_ms),
            class,
            trace: Default::default(),
            reply: tx,
        },
        rx,
    )
}

#[test]
fn batches_bounded_edf_ordered_and_lossless() {
    // Random (max_batch, deadline multiset) cases: draining the whole queue
    // must emit 1..=max_batch-sized batches, in globally non-decreasing
    // deadline order, with every pushed id appearing exactly once.
    forall(
        0xB47C,
        200,
        |r| {
            let max_batch = r.range(1, 6) as usize;
            let n = r.range(0, 40) as usize;
            let deadlines: Vec<u64> = (0..n).map(|_| r.range(0, 10_000)).collect();
            (max_batch, deadlines)
        },
        |case| {
            let (max_batch, deadlines) = case;
            let b = Batcher::new(BatcherConfig {
                max_batch: *max_batch,
                window: Duration::ZERO,
                deadline_margin: Duration::ZERO,
                ..BatcherConfig::default()
            });
            let now = Instant::now();
            let mut rxs = Vec::new();
            for (i, &d) in deadlines.iter().enumerate() {
                let (rq, rx) = req(i as u64, now, d);
                b.push(rq).unwrap();
                rxs.push(rx);
            }
            b.close();
            let mut seen: Vec<(Instant, u64)> = Vec::new();
            while let Some(batch) = b.next_batch() {
                if batch.is_empty() || batch.len() > *max_batch {
                    return false;
                }
                seen.extend(batch.into_iter().map(|r| (r.deadline, r.id)));
            }
            if seen.len() != deadlines.len() {
                return false; // a request was dropped (or duplicated)
            }
            let mut ids: Vec<u64> = seen.iter().map(|&(_, id)| id).collect();
            ids.sort_unstable();
            if ids != (0..deadlines.len() as u64).collect::<Vec<_>>() {
                return false;
            }
            // EDF: deadlines never decrease across the drained stream.
            seen.windows(2).all(|w| w[0].0 <= w[1].0)
        },
    );
}

#[test]
fn class_major_edf_order_holds_under_random_mixes() {
    // With mixed SLO classes the drain order must be class-major (higher
    // priority strictly first), EDF within each class, still lossless.
    forall(
        0xC1A5,
        200,
        |r| {
            let n = r.range(0, 40) as usize;
            (0..n)
                .map(|_| (r.range(0, 10_000), r.below(3) as usize))
                .collect::<Vec<(u64, usize)>>()
        },
        |reqs| {
            let b = Batcher::new(BatcherConfig {
                max_batch: 4,
                window: Duration::ZERO,
                deadline_margin: Duration::ZERO,
                ..BatcherConfig::default()
            });
            let now = Instant::now();
            let mut rxs = Vec::new();
            for (i, &(d, c)) in reqs.iter().enumerate() {
                let (rq, rx) = req_class(i as u64, now, d, SloClass::from_index(c));
                b.push(rq).unwrap();
                rxs.push(rx);
            }
            b.close();
            let mut seen: Vec<(std::cmp::Reverse<u8>, Instant)> = Vec::new();
            let mut count = 0usize;
            while let Some(batch) = b.next_batch() {
                count += batch.len();
                seen.extend(
                    batch
                        .into_iter()
                        .map(|r| (std::cmp::Reverse(r.class.priority()), r.deadline)),
                );
            }
            count == reqs.len() && seen.windows(2).all(|w| w[0] <= w[1])
        },
    );
}

#[test]
fn urgent_deadline_closes_batch_before_window() {
    // A 30 s window would sink any real-time deadline; the margin check
    // must close the batch immediately when the EDF head is urgent.
    let b = Batcher::new(BatcherConfig {
        max_batch: 8,
        window: Duration::from_secs(30),
        deadline_margin: Duration::from_millis(100),
        ..BatcherConfig::default()
    });
    let now = Instant::now();
    let (far, _x1) = req(2, now, 60_000);
    let (urgent, _x2) = req(1, now, 10); // inside the margin
    b.push(far).unwrap();
    b.push(urgent).unwrap();
    let t0 = Instant::now();
    let batch = b.next_batch().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "batch must close early, not wait the window: {:?}",
        t0.elapsed()
    );
    assert_eq!(batch.first().unwrap().id, 1, "EDF head pops first");
    assert_eq!(batch.len(), 2, "queued requests ride along");
}

#[test]
fn relaxed_deadlines_wait_for_the_window() {
    // Control for the early-close property: with every deadline far outside
    // the margin, the batcher waits for late joiners.
    let b = Arc::new(Batcher::new(BatcherConfig {
        max_batch: 4,
        window: Duration::from_millis(60),
        deadline_margin: Duration::from_millis(1),
        ..BatcherConfig::default()
    }));
    let now = Instant::now();
    let (first, _x1) = req(0, now, 60_000);
    b.push(first).unwrap();
    let b2 = b.clone();
    let joiner = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(15));
        let (late, x) = req(1, Instant::now(), 60_000);
        b2.push(late).unwrap();
        std::mem::forget(x);
    });
    let batch = b.next_batch().unwrap();
    joiner.join().unwrap();
    assert_eq!(batch.len(), 2, "late arrival joins the open window");
}

#[test]
fn random_concurrent_bursts_never_drop_requests() {
    // Producer pushes Poisson-ish bursts while two consumers race to drain:
    // every id must surface exactly once across both consumers.
    let mut rng = SplitMix64::new(0xB0B5);
    let b = Arc::new(Batcher::new(BatcherConfig {
        max_batch: 3,
        window: Duration::from_micros(200),
        deadline_margin: Duration::from_micros(50),
        ..BatcherConfig::default()
    }));
    let total: u64 = 300;
    let drained: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let consumers: Vec<_> = (0..2)
        .map(|_| {
            let b = b.clone();
            let d = drained.clone();
            std::thread::spawn(move || {
                while let Some(batch) = b.next_batch() {
                    assert!(!batch.is_empty() && batch.len() <= 3);
                    d.lock().unwrap().extend(batch.iter().map(|r| r.id));
                }
            })
        })
        .collect();
    let mut rxs = Vec::new();
    let now = Instant::now();
    let mut id = 0u64;
    while id < total {
        let burst = rng.range(1, 8).min(total - id);
        for _ in 0..burst {
            let (rq, rx) = req(id, now, rng.range(1, 50));
            b.push(rq).unwrap();
            rxs.push(rx);
            id += 1;
        }
        if rng.below(3) == 0 {
            std::thread::sleep(Duration::from_micros(rng.range(0, 300)));
        }
    }
    b.close();
    for c in consumers {
        c.join().unwrap();
    }
    let mut ids = drained.lock().unwrap().clone();
    ids.sort_unstable();
    assert_eq!(ids.len() as u64, total, "no request may be dropped");
    assert!(
        ids.iter().enumerate().all(|(i, &v)| v == i as u64),
        "every request drained exactly once"
    );
}
