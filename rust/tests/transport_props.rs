//! Transport-layer properties and fault-plan soaks.
//!
//! * Ring wraparound behaves as a bounded FIFO (model-checked against a
//!   `VecDeque` reference under random op sequences).
//! * Sequence numbers are strictly monotone across backpressure.
//! * The buffer pool never hands one registered buffer to two owners and
//!   recycles buffers zeroed.
//! * Under a hostile fault plan (drops + duplicates + reorders +
//!   corruption) every request still gets exactly one outcome, duplicate
//!   completions die in the seq dedup (never reaching the router's
//!   saturating-CAS backstop), and the pool drains to zero at teardown —
//!   no descriptor leaks.
//! * A stalled device converts to typed client failures, never a hang or
//!   a panic.
//! * The exactly-one-response migration contract holds with shim-backed
//!   lanes under fault injection.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, PipelinedBackend, Server, ServerConfig,
};
use superlip::transport::{
    BufferPool, FaultPlan, LinkModel, Ring, TransportBackend, TransportConfig,
};
use superlip::util::proptest::forall;
use superlip::util::SplitMix64;

/// Deterministic stub: logits[c] = sum(image) + c.
struct Stub {
    elems: usize,
    classes: usize,
    max_batch: usize,
    delay: Duration,
}

impl InferBackend for Stub {
    fn image_elems(&self) -> usize {
        self.elems
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn infer(&self, images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(n * self.classes);
        for i in 0..n {
            let s: f32 = images[i * self.elems..(i + 1) * self.elems].iter().sum();
            for c in 0..self.classes {
                out.push(s + c as f32);
            }
        }
        Ok(out)
    }
}

fn stub_factory(delay: Duration) -> BackendFactory {
    Box::new(move || {
        Ok(Box::new(Stub {
            elems: 4,
            classes: 2,
            max_batch: 4,
            delay,
        }) as Box<dyn InferBackend>)
    })
}

#[test]
fn ring_wraparound_matches_fifo_model() {
    // Random push/pop sequences over a tiny ring, long enough that the
    // monotone head/tail wrap the slot array many times; a VecDeque is
    // the reference semantics.
    forall(
        0x81b6,
        60,
        |r| (0..200).map(|_| r.below(5) < 3).collect::<Vec<bool>>(),
        |ops| {
            let ring: Ring<u64> = Ring::new(4);
            let mut model: VecDeque<u64> = VecDeque::new();
            let mut next = 0u64;
            for &push in ops {
                if push {
                    match ring.try_push(next) {
                        Ok(()) => {
                            if model.len() >= 4 {
                                return false; // accepted past capacity
                            }
                            model.push_back(next);
                        }
                        Err(v) => {
                            // Full hands the value back untouched.
                            if v != next || model.len() != 4 {
                                return false;
                            }
                        }
                    }
                    next += 1;
                } else if ring.try_pop() != model.pop_front() {
                    return false;
                }
                if ring.len() != model.len() {
                    return false;
                }
            }
            // Drain: FIFO order must survive every wraparound.
            while let Some(got) = ring.try_pop() {
                if model.pop_front() != Some(got) {
                    return false;
                }
            }
            model.is_empty()
        },
    );
}

#[test]
fn sequence_numbers_are_strictly_monotone_across_backpressure() {
    let cfg = TransportConfig {
        ring_capacity: 4,
        pool_buffers: 3,
        pipeline_depth: 3,
        // A visible dwell so submits genuinely outrun the device and hit
        // typed backpressure mid-stream.
        link: LinkModel {
            latency: Duration::from_micros(300),
            gbps: 0.0,
        },
        ..TransportConfig::default()
    };
    let tb = TransportBackend::over_shim(cfg, stub_factory(Duration::ZERO)).unwrap();
    let mut last: Option<u64> = None;
    let mut accepted = 0usize;
    let mut refused = 0usize;
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while accepted < 40 {
        let mut fill = |dst: &mut [f32]| dst.fill(1.0);
        match tb.submit_batch(1, deadline, &mut fill) {
            Ok(seq) => {
                // Strictly monotone: a refused submit must not have
                // consumed (or reused) a sequence number.
                if let Some(p) = last {
                    assert_eq!(seq, p + 1, "seq gap or reuse after backpressure");
                }
                last = Some(seq);
                accepted += 1;
            }
            Err(_) => refused += 1,
        }
        for _ in tb.reap(Duration::from_micros(200)) {}
    }
    while tb.in_flight() > 0 {
        for _ in tb.reap(Duration::from_millis(1)) {}
    }
    assert!(refused > 0, "backpressure never exercised");
    assert_eq!(tb.stats().submitted, 40);
}

#[test]
fn pool_never_duplicates_an_owner_and_recycles_zeroed() {
    forall(
        0x9001,
        40,
        |r| (0..120).map(|_| r.below(6)).collect::<Vec<u64>>(),
        |ops| {
            let pool = BufferPool::new(3, 8);
            let mut held: Vec<superlip::transport::PooledBuf> = Vec::new();
            for &op in ops {
                if op < 4 {
                    match pool.try_acquire() {
                        Ok(mut b) => {
                            // One owner per registered buffer, ever.
                            if held.iter().any(|h| h.id() == b.id()) {
                                return false;
                            }
                            // Recycled buffers come back zeroed through
                            // reset_len — a stale payload must never leak
                            // into the next descriptor.
                            b.reset_len(8);
                            if b.iter().any(|&x| x != 0.0) {
                                return false;
                            }
                            b[op as usize % 8] = 7.0; // dirty it for the next cycle
                            held.push(b);
                        }
                        Err(_) => {
                            if pool.in_use() != 3 {
                                return false; // exhausted only when all out
                            }
                        }
                    }
                } else if !held.is_empty() {
                    held.remove((op as usize) % held.len());
                }
                if pool.in_use() != held.len() {
                    return false;
                }
            }
            drop(held);
            pool.in_use() == 0
        },
    );
}

/// The headline soak: a hostile device (drops + duplicates + reorders +
/// corruption) against the synchronous retry path. Every request resolves
/// exactly once, duplicate completions are absorbed by the seq dedup, and
/// teardown leaves the pool fully recycled — zero descriptor leaks.
#[test]
fn fault_soak_exactly_one_outcome_and_no_descriptor_leaks() {
    let cfg = TransportConfig {
        ring_capacity: 8,
        pool_buffers: 4,
        reap_timeout: Duration::from_millis(25),
        max_retries: 12,
        faults: Some(FaultPlan {
            seed: 0xfa117,
            drop: 0.10,
            duplicate: 0.15,
            reorder: 0.20,
            corrupt: 0.10,
            stall_after: None,
        }),
        ..TransportConfig::default()
    };
    let tb = TransportBackend::over_shim(cfg, stub_factory(Duration::ZERO)).unwrap();
    let pool = tb.pool().clone(); // watch recycling past the drop below
    let mut ok = 0u64;
    let mut failed = 0u64;
    for i in 0..60u32 {
        let img = vec![i as f32; 8];
        match tb.infer(&img, 2) {
            Ok(logits) => {
                // Exactly one verified outcome, with the right payload —
                // a reordered or duplicated completion must never leak a
                // different request's logits into this one.
                assert_eq!(logits.len(), 4);
                assert_eq!(logits[0], 4.0 * i as f32);
                assert_eq!(logits[1], 4.0 * i as f32 + 1.0);
                ok += 1;
            }
            Err(_) => failed += 1, // typed retry-budget exhaustion — allowed
        }
    }
    assert_eq!(ok + failed, 60, "every request resolved exactly once");
    assert!(ok >= 55, "retry budget should absorb nearly all faults ({ok})");
    let stats = tb.stats();
    assert!(
        stats.ignored > 0 || stats.timeouts == 0,
        "duplicates/stragglers are counted, not delivered: {stats:?}"
    );
    assert_eq!(tb.in_flight(), 0);
    drop(tb);
    assert_eq!(pool.in_use(), 0, "descriptor leak: pool not fully recycled");
}

/// Same hostility through the full server (pipelined worker loop):
/// `completed + disconnected == sent`, nobody answered twice, and the
/// router's outstanding books balance to zero — duplicate completions hit
/// the transport dedup, not `PlanRouter::complete`.
#[test]
fn server_fault_soak_conserves_every_request() {
    let cfg = TransportConfig {
        ring_capacity: 8,
        pipeline_depth: 3,
        reap_timeout: Duration::from_millis(20),
        max_retries: 8,
        faults: Some(FaultPlan {
            seed: 0x50a4 ^ 0x5eed,
            drop: 0.05,
            duplicate: 0.12,
            reorder: 0.12,
            corrupt: 0.05,
            stall_after: None,
        }),
        ..TransportConfig::default()
    };
    let spec = LaneSpec {
        model: "m".into(),
        factories: vec![TransportBackend::shim_factory(
            cfg,
            stub_factory(Duration::ZERO),
        )],
        batcher: BatcherConfig::default(),
    };
    let srv = Arc::new(Server::start_plan(vec![spec], ServerConfig::default()));
    const SENT: usize = 120;
    let d = Duration::from_secs(30);
    let rxs: Vec<_> = (0..SENT)
        .map(|i| srv.submit_to("m", vec![i as f32, 0.0, 0.0, 0.0], d).unwrap())
        .collect();
    let mut completed = 0usize;
    let mut disconnected = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(20)) {
            Ok(r) => {
                assert_eq!(r.logits[0], i as f32, "cross-wired response");
                assert!(rx.try_recv().is_err(), "request {i} answered twice");
                completed += 1;
            }
            Err(_) => disconnected += 1, // typed fail-closed — allowed
        }
    }
    assert_eq!(completed + disconnected, SENT);
    assert!(completed >= SENT - 5, "faults should mostly be absorbed ({completed})");
    assert_eq!(
        srv.lane_load().iter().sum::<u64>(),
        0,
        "router books must balance — duplicates may not double-complete"
    );
    let m = srv.shutdown();
    assert_eq!(m.arrivals(), SENT as u64);
    assert_eq!(m.completed(), completed);
}

/// The stalled-device drill at the serving layer: a device that wedges
/// after 0 descriptors converts every request into a bounded, typed
/// disconnect — no hang, no panic, books balanced.
#[test]
fn stalled_device_fails_closed_without_hanging() {
    let cfg = TransportConfig {
        reap_timeout: Duration::from_millis(5),
        max_retries: 0,
        faults: Some(FaultPlan {
            stall_after: Some(0),
            ..FaultPlan::default()
        }),
        ..TransportConfig::default()
    };
    let spec = LaneSpec {
        model: "m".into(),
        factories: vec![TransportBackend::shim_factory(
            cfg,
            stub_factory(Duration::ZERO),
        )],
        batcher: BatcherConfig::default(),
    };
    let srv = Arc::new(Server::start_plan(vec![spec], ServerConfig::default()));
    let rxs: Vec<_> = (0..10)
        .map(|i| {
            srv.submit_to("m", vec![i as f32, 0.0, 0.0, 0.0], Duration::from_secs(5))
                .unwrap()
        })
        .collect();
    for rx in rxs {
        // Fail-closed within the worker's submit patience — a stalled
        // ring must never strand a client on an open channel.
        assert!(
            rx.recv_timeout(Duration::from_secs(10)).is_err(),
            "stalled device cannot produce a completion"
        );
    }
    assert_eq!(srv.lane_load().iter().sum::<u64>(), 0);
    let m = srv.shutdown();
    assert_eq!(m.arrivals(), 10);
    assert_eq!(m.completed(), 0);
}

/// The migration exactly-one-response contract, now with every lane
/// generation behind a faulty shim transport: make-before-break handoffs
/// while the device drops/duplicates/reorders completions.
#[test]
fn migration_exactly_one_response_with_shim_lanes() {
    fn shim_lane(tag_seed: u64) -> LaneSpec {
        let cfg = TransportConfig {
            reap_timeout: Duration::from_millis(20),
            max_retries: 8,
            faults: Some(FaultPlan {
                seed: 0xd1f ^ tag_seed,
                drop: 0.03,
                duplicate: 0.10,
                reorder: 0.10,
                corrupt: 0.03,
                stall_after: None,
            }),
            ..TransportConfig::default()
        };
        LaneSpec {
            model: "m".into(),
            factories: vec![TransportBackend::shim_factory(
                cfg,
                stub_factory(Duration::from_micros(200)),
            )],
            batcher: BatcherConfig {
                max_batch: 4,
                window: Duration::from_micros(300),
                deadline_margin: Duration::from_micros(300),
                ..BatcherConfig::default()
            },
        }
    }

    let srv = Arc::new(Server::start_plan(vec![shim_lane(0)], ServerConfig::default()));
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let migrator = {
        let srv = srv.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0x316);
            let mut old = 0usize;
            for gen in 1..=6u64 {
                let fresh = srv.add_lane(shim_lane(gen));
                srv.retire_lane(old).expect("old lane was live");
                old = fresh;
                std::thread::sleep(Duration::from_millis(5 + rng.below(10)));
                if done.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
            }
        })
    };
    const SENT: usize = 150;
    let d = Duration::from_secs(30);
    let mut rxs = Vec::with_capacity(SENT);
    for i in 0..SENT {
        rxs.push((
            i as f32,
            srv.submit_to("m", vec![i as f32, 0.0, 0.0, 0.0], d).unwrap(),
        ));
        std::thread::sleep(Duration::from_micros(300));
    }
    done.store(true, std::sync::atomic::Ordering::Relaxed);
    let mut completed = 0usize;
    for (v, rx) in rxs {
        match rx.recv_timeout(Duration::from_secs(20)) {
            Ok(r) => {
                assert_eq!(r.logits[0], v, "response routed to the wrong request");
                assert!(rx.try_recv().is_err(), "request {v} answered twice");
                completed += 1;
            }
            Err(_) => {} // typed fail-closed under fault injection — allowed
        }
    }
    migrator.join().expect("migrator panicked");
    assert!(
        completed >= SENT - 8,
        "migration + faults lost too many: {completed}/{SENT}"
    );
    assert_eq!(srv.lane_load().iter().sum::<u64>(), 0);
    srv.shutdown();
}
