//! Cross-module integration tests: DSE → partition → XFER → simulator →
//! energy pipelines over the real network zoo, plus the fleet planner →
//! plan-driven serving path end-to-end.

use std::time::Duration;
use superlip::analytic::{
    check_feasible, network_latency, xfer_network_latency, Design, XferMode,
};
use superlip::coordinator::SuperLip;
use superlip::dse;
use superlip::energy::{self, PowerModel};
use superlip::fleet::{
    equal_split, run_scenario, FleetSpec, Planner, PlannerConfig, ScenarioConfig, WorkloadSpec,
};
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::{FpgaSpec, Precision};
use superlip::sim::{simulate_network, SimConfig};

fn setup() -> (FpgaSpec, SimConfig) {
    let f = FpgaSpec::zcu102();
    let c = SimConfig::zcu102(&f);
    (f, c)
}

#[test]
fn dse_plus_sim_pipeline_all_networks() {
    // For every zoo network: per-layer DSE designs are feasible; the
    // simulated latency tracks the analytic model within 10%.
    let (fpga, cfg) = setup();
    for net in zoo::all() {
        let uni = dse::best_uniform_design(&net, &fpga, Precision::Fixed16);
        let model = network_latency(&net, &uni.design);
        let sim = simulate_network(
            &net,
            &uni.design,
            &Factors::single(),
            &fpga,
            &cfg,
            XferMode::Xfer,
        )
        .cycles;
        let dev = (sim as f64 - model as f64).abs() / sim as f64;
        assert!(dev < 0.10, "{}: dev {dev}", net.name);
    }
}

#[test]
fn figure15_headline_shapes() {
    // AlexNet & VGG super-linear at 2 FPGAs; SqueezeNet sub-linear (its
    // 1x1 convs are compute-bound); all latencies fall monotonically to 16.
    let (fpga, cfg) = setup();
    let cases = [
        ("AlexNet", Design::fixed16(128, 10, 7, 14), true),
        ("VGG16", Design::fixed16(64, 25, 7, 14), true),
        ("SqueezeNet", Design::fixed16(64, 16, 7, 14), false),
    ];
    for (name, d, expect_super) in cases {
        let net = zoo::by_name(name).unwrap();
        let mut prev = u64::MAX;
        let mut single = 0;
        for n in [1u64, 2, 4, 8, 16] {
            let (f, _) = dse::best_factors(&net, &d, &fpga, n, XferMode::Xfer);
            let cycles = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer).cycles;
            assert!(cycles <= prev, "{name}: latency rose at {n} FPGAs");
            prev = cycles;
            if n == 1 {
                single = cycles;
            }
            if n == 2 {
                let speedup = single as f64 / cycles as f64;
                if expect_super {
                    assert!(speedup > 2.0, "{name}: 2-FPGA speedup {speedup}");
                } else {
                    assert!(
                        speedup < 2.3,
                        "{name} should be ~linear (compute-bound): {speedup}"
                    );
                }
            }
        }
    }
}

#[test]
fn energy_efficiency_improves_with_xfer_scaling() {
    // §5E: EE improves vs single-FPGA for the memory-bound networks.
    let (fpga, cfg) = setup();
    let net = zoo::alexnet();
    let d = Design::fixed16(128, 10, 7, 14);
    let k_max = net.conv_layers().map(|l| l.k).max().unwrap();
    let usage = check_feasible(&d, &fpga, k_max).unwrap();
    let total_ops: u64 = net.conv_layers().map(|l| l.ops()).sum();

    let ee = |n: u64| {
        let (f, _) = dse::best_factors(&net, &d, &fpga, n, XferMode::Xfer);
        let sim = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer);
        let gops = energy::gops(total_ops, sim.cycles, d.precision);
        gops / PowerModel::new(n).watts(&d, &usage)
    };
    let ee1 = ee(1);
    let ee4 = ee(4);
    assert!(ee4 > ee1, "4-FPGA EE {ee4} should beat single {ee1}");
}

#[test]
fn coordinator_full_plan_consistency() {
    let slip = SuperLip::default();
    let net = zoo::alexnet();
    let plan = slip.plan(&net, Precision::Fixed16, 4).unwrap();
    assert_eq!(plan.factors.num_fpgas(), 4);
    assert!(plan.bandwidth_ok);
    // The plan's model cycles must equal re-evaluating its own design.
    let re = xfer_network_latency(
        &net,
        &plan.design,
        &plan.factors,
        &slip.fpga,
        XferMode::Xfer,
    );
    assert_eq!(plan.model_cycles, re);
    // sim ≥ model (the simulator only adds real-world cost).
    assert!(plan.sim_cycles >= plan.model_cycles);
}

#[test]
fn xfer_dominates_baseline_across_zoo_and_sizes() {
    let (fpga, cfg) = setup();
    for net in zoo::all() {
        let d = Design::fixed16(64, 16, 7, 14);
        for n in [2u64, 4] {
            let (fb, _) = dse::best_factors(&net, &d, &fpga, n, XferMode::Baseline);
            let base = simulate_network(&net, &d, &fb, &fpga, &cfg, XferMode::Baseline).cycles;
            let (fx, _) = dse::best_factors(&net, &d, &fpga, n, XferMode::Xfer);
            let xfer = simulate_network(&net, &d, &fx, &fpga, &cfg, XferMode::Xfer).cycles;
            assert!(
                xfer <= base,
                "{} n={n}: xfer {xfer} > baseline {base}",
                net.name
            );
        }
    }
}

#[test]
fn float_vs_fixed_tradeoff() {
    // Table 2's precision story: fx16 strictly faster than f32 at the same
    // cluster size (more MACs per DSP + double the clock).
    let slip = SuperLip::default();
    let net = zoo::alexnet();
    let pf = slip.plan(&net, Precision::Float32, 2).unwrap();
    let px = slip.plan(&net, Precision::Fixed16, 2).unwrap();
    assert!(
        px.sim_ms < pf.sim_ms,
        "fx16 {} ms !< f32 {} ms",
        px.sim_ms,
        pf.sim_ms
    );
    assert!(px.gops > pf.gops);
}

#[test]
fn fleet_planner_to_sim_serving_end_to_end() {
    // 4-board fleet, alexnet (light) + vgg16 (heavy). The mix is
    // self-calibrated: vgg16's deadline sits strictly between its 3-board
    // and 2-board service times, so the planner must discover the 1/3
    // split, and the naive equal split provably misses.
    let planner = Planner::new(
        FleetSpec::homogeneous(4, FpgaSpec::zcu102()),
        PlannerConfig::default(),
    );
    let alex1 = planner.service_ms("alexnet", 1).unwrap();
    let vgg3 = planner.service_ms("vgg16", 3).unwrap();
    let vgg2 = planner.service_ms("vgg16", 2).unwrap();
    assert!(vgg3 < vgg2);
    let mix = vec![
        WorkloadSpec::new(
            "alexnet",
            0.05 / (alex1 / 1e3),
            Duration::from_secs_f64(4.0 * alex1 / 1e3),
        ),
        WorkloadSpec::new(
            "vgg16",
            0.15 / (vgg3 / 1e3),
            Duration::from_secs_f64((vgg3 + vgg2) / 2.0 / 1e3),
        ),
    ];
    let plan = planner.plan(&mix).unwrap();
    assert_eq!(plan.allocation(), vec![1, 3], "{}", plan.summary());
    assert!(plan.worst_risk.is_finite());

    // The planner's split can never be worse than any fixed allocation it
    // also enumerated — including the naive equal split.
    let naive = planner.plan_allocation(&mix, &equal_split(4, 2)).unwrap();
    assert!(plan.worst_risk <= naive.worst_risk);
    assert!(
        !naive.worst_risk.is_finite(),
        "vgg16 on 2 boards cannot meet its deadline"
    );

    // Serve the planned fleet for real: plan-driven router over
    // sim-cluster backends, no hard-coded single backend anywhere.
    let stats = run_scenario(
        &plan,
        &ScenarioConfig {
            requests_per_model: 15,
            seed: 42,
            time_scale: 1.0,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(stats.len(), 2);
    for s in &stats {
        assert_eq!(s.completed, 15, "{}: all requests served", s.model);
        assert!(s.p99_ms >= s.p50_ms && s.p50_ms > 0.0);
    }
    let vgg = stats.iter().find(|s| s.model == "vgg16").unwrap();
    assert_eq!(vgg.n_boards, 3);
    // Service fits the deadline with ~20% headroom and ρ ≈ 0.15; the bulk
    // of requests must make it (generous bound for CI jitter).
    assert!(vgg.miss_rate < 0.5, "planned vgg16 misses too much: {vgg:?}");
}

#[test]
fn infeasible_cluster_requests_degrade_gracefully() {
    // Asking for more FPGAs than any partition supports must still return
    // the best factorization of n (possibly leaving slices empty), never
    // panic.
    let (fpga, _) = setup();
    let net = zoo::squeezenet();
    let d = Design::fixed16(64, 16, 7, 14);
    let (f, cycles) = dse::best_factors(&net, &d, &fpga, 16, XferMode::Xfer);
    assert_eq!(f.num_fpgas(), 16);
    assert!(cycles > 0);
}
