//! Unit/property tests for the 2D-torus cluster topology
//! (`partition::topology::Torus`, paper §4.4 / Figure 10): link degrees,
//! the `Pm × (Pb·Pr·Pc)` shape contract of `for_factors`, and the
//! Property 2 traffic-balance rotation (each ring visits every peer
//! exactly once).

use std::collections::BTreeSet;
use superlip::partition::{Factors, Torus};
use superlip::util::proptest::forall;

#[test]
fn every_node_has_two_in_and_two_out_links() {
    // "Each FPGA has two incoming links and two outgoing links" — one per
    // torus dimension, whenever both dimensions are real.
    for rows in 2..=5u64 {
        for cols in 2..=5u64 {
            let t = Torus { rows, cols };
            assert_eq!(t.out_degree(), 2);
            for id in 0..t.num_nodes() {
                let n = t.node(id);
                let (down, right) = (t.down(n), t.right(n));
                assert_ne!(down, right, "{rows}x{cols} node {id}: out links distinct");
                assert_ne!(down, n, "no self-link on a real column ring");
                assert_ne!(right, n, "no self-link on a real row ring");
                let in_degree: u64 = (0..t.num_nodes())
                    .map(|uid| {
                        let u = t.node(uid);
                        u64::from(t.down(u) == n) + u64::from(t.right(u) == n)
                    })
                    .sum();
                assert_eq!(in_degree, 2, "{rows}x{cols} node {id}: in-degree");
            }
        }
    }
}

#[test]
fn collapsed_dimensions_carry_no_real_links() {
    let line = Torus { rows: 1, cols: 4 };
    assert_eq!(line.out_degree(), 1);
    let n = line.node(2);
    assert_eq!(line.down(n), n, "collapsed column ring is a self-loop");
    assert_ne!(line.right(n), n);
    let single = Torus { rows: 1, cols: 1 };
    assert_eq!(single.out_degree(), 0);
}

#[test]
fn for_factors_shape_is_pbprpc_rows_by_pm_cols() {
    // §4.4 "Organization": rows = Pb·Pr·Pc (weight-sharing groups),
    // cols = Pm (IFM-sharing groups) — for every factorization.
    forall(
        0x7012,
        300,
        |r| (r.range(1, 3), r.range(1, 3), r.range(1, 3), r.range(1, 4)),
        |&(pb, pr, pc, pm)| {
            let f = Factors::new(pb, pr, pc, pm);
            let t = Torus::for_factors(&f);
            t.rows == pb * pr * pc && t.cols == pm && t.num_nodes() == f.num_fpgas()
        },
    );
}

#[test]
fn ring_rotation_visits_every_peer_exactly_once() {
    // Property 2 (traffic balance): rotating along a row visits every
    // column exactly once and returns home; same for columns — so the
    // all-to-all exchange needs no routing and no link is oversubscribed.
    let t = Torus { rows: 3, cols: 4 };
    for id in 0..t.num_nodes() {
        let start = t.node(id);
        let mut cur = start;
        let mut cols_seen = BTreeSet::new();
        for _ in 0..t.cols {
            cur = t.right(cur);
            assert_eq!(cur.row, start.row, "row ring stays in its row");
            assert!(cols_seen.insert(cur.col), "column revisited early");
        }
        assert_eq!(cur, start, "row ring closes after `cols` hops");
        assert_eq!(cols_seen.len() as u64, t.cols);

        let mut cur = start;
        let mut rows_seen = BTreeSet::new();
        for _ in 0..t.rows {
            cur = t.down(cur);
            assert_eq!(cur.col, start.col, "column ring stays in its column");
            assert!(rows_seen.insert(cur.row), "row revisited early");
        }
        assert_eq!(cur, start, "column ring closes after `rows` hops");
        assert_eq!(rows_seen.len() as u64, t.rows);
    }
}

#[test]
fn ring_schedule_delivers_all_chunks_for_any_ring_size() {
    for p in 1..=8u64 {
        let steps = Torus::ring_schedule(p);
        assert_eq!(steps.len() as u64, p.saturating_sub(1));
        let mut own: Vec<Vec<bool>> = (0..p)
            .map(|i| (0..p).map(|c| c == i).collect())
            .collect();
        for step in &steps {
            assert_eq!(step.len() as u64, p, "every node forwards each step");
            let snapshot = own.clone();
            for &(from, to, chunk) in step {
                assert!(
                    snapshot[from as usize][chunk as usize],
                    "p={p}: node {from} forwarded chunk {chunk} it doesn't hold"
                );
                own[to as usize][chunk as usize] = true;
            }
        }
        for (i, holds) in own.iter().enumerate() {
            assert!(holds.iter().all(|&h| h), "p={p}: node {i} missing a chunk");
        }
    }
}
