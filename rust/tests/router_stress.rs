//! Concurrency stress for the lock-free `PlanRouter`: submitters hammer
//! `route`/`complete` while a control-plane thread grows and retires lanes
//! (`add_lane` + `add_lane_route` + `deroute`) the whole time.
//!
//! Three properties must hold under the race, for both policies:
//!
//! 1. **No panic / no wrap** — the snapshot swap and the saturating
//!    outstanding counters never trip an assertion or index out of range.
//! 2. **Conservation** — at any quiescent point, the summed per-lane
//!    outstanding equals routes minus completes (each submitter completes
//!    exactly the lanes it routed, exactly once).
//! 3. **Retirement is clean** — once `deroute(lane)` has returned, a
//!    `route` that STARTS afterwards never picks that lane. Each submitter
//!    snapshots the retirement flags before routing; the mutator raises a
//!    lane's flag only after its `deroute` call returned, so a pre-raised
//!    flag on the picked lane is a linearization violation.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use superlip::serving::{PlanRouter, RoutePolicy};
use superlip::util::SplitMix64;

const MODEL: &str = "m";
const SUBMITTERS: usize = 3;
const SUBMIT_ITERS: usize = 4_000;
const MUTATIONS: usize = 150;
/// 2 seed lanes + one lane added per mutator iteration.
const MAX_LANES: usize = 2 + MUTATIONS;

fn stress(policy: RoutePolicy) {
    let router = Arc::new(PlanRouter::new(policy, 2));
    router.add_route(MODEL, vec![0, 1]);

    // retired[l] is raised strictly AFTER deroute(l) returns.
    let retired: Arc<Vec<AtomicBool>> =
        Arc::new((0..MAX_LANES).map(|_| AtomicBool::new(false)).collect());
    let routed_total = AtomicU64::new(0);
    let completed_total = AtomicU64::new(0);

    std::thread::scope(|s| {
        // Control plane: stand a new lane up, point the model at it, then
        // (usually) retire the oldest still-active lane — a rolling
        // migration that keeps 2-3 lanes live at all times.
        s.spawn(|| {
            let mut rng = SplitMix64::new(0xc0117e57);
            let mut active: Vec<usize> = vec![0, 1];
            for _ in 0..MUTATIONS {
                let l = router.add_lane();
                router.add_lane_route(MODEL, l);
                active.push(l);
                if active.len() > 2 && rng.below(4) != 0 {
                    let victim = active.remove(0);
                    router.deroute(victim);
                    retired[victim].store(true, Ordering::SeqCst);
                }
                if rng.below(8) == 0 {
                    std::thread::yield_now();
                }
            }
        });

        for t in 0..SUBMITTERS {
            let router = Arc::clone(&router);
            let retired = Arc::clone(&retired);
            let (routed_total, completed_total) = (&routed_total, &completed_total);
            s.spawn(move || {
                let mut rng = SplitMix64::new(0x5eed ^ t as u64);
                // Routes not yet completed (lane indices, possibly dups).
                let mut in_flight: Vec<usize> = Vec::new();
                let mut routed = 0u64;
                let mut completed = 0u64;
                for _ in 0..SUBMIT_ITERS {
                    // Snapshot retirement flags BEFORE the route starts.
                    let pre: Vec<bool> =
                        retired.iter().map(|f| f.load(Ordering::SeqCst)).collect();
                    if let Some(lane) = router.route(MODEL) {
                        assert!(lane < MAX_LANES);
                        assert!(
                            !pre[lane],
                            "lane {lane} was retired before this route started"
                        );
                        in_flight.push(lane);
                        routed += 1;
                    }
                    // Complete a random in-flight request about as often
                    // as we route, keeping a small standing backlog.
                    if !in_flight.is_empty() && rng.below(3) != 0 {
                        let i = rng.below(in_flight.len() as u64) as usize;
                        router.complete(in_flight.swap_remove(i));
                        completed += 1;
                    }
                }
                // Drain the backlog so the final census is exact.
                for lane in in_flight {
                    router.complete(lane);
                    completed += 1;
                }
                routed_total.fetch_add(routed, Ordering::SeqCst);
                completed_total.fetch_add(completed, Ordering::SeqCst);
            });
        }
    });

    // Quiescent: every route was completed exactly once, so every lane's
    // outstanding must be back to zero — wrap or a lost decrement would
    // leave a nonzero (possibly enormous) residue.
    let routed = routed_total.load(Ordering::SeqCst);
    let completed = completed_total.load(Ordering::SeqCst);
    assert_eq!(routed, completed);
    assert!(routed > 0, "stress must actually route");
    let residue: u64 = router.load().iter().sum();
    assert_eq!(residue, 0, "conservation violated: load {:?}", router.load());
    // Memory: snapshots retained are bounded by mutations, not traffic.
    // (2 per mutator iteration: add_lane + add_lane_route, +1 per deroute,
    // +1 initial add_route.)
    assert!(
        router.snapshots_retained() <= 1 + 3 * MUTATIONS + 1,
        "retained {} snapshots for {} mutations",
        router.snapshots_retained(),
        MUTATIONS
    );
}

#[test]
fn stress_least_outstanding_under_live_mutation() {
    stress(RoutePolicy::LeastOutstanding);
}

#[test]
fn stress_round_robin_under_live_mutation() {
    stress(RoutePolicy::RoundRobin);
}
