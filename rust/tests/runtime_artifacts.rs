//! Runtime tests against the real AOT artifacts (skipped with a note when
//! `artifacts/` is absent — run `make artifacts` first). The tests that
//! actually execute artifacts additionally require the `pjrt` feature:
//! the default build's stub runtime refuses to compile HLO, so without
//! the gate they would fail (not skip) on a machine that has artifacts.

use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use superlip::runtime::ModelExecutor;
use superlip::runtime::{Manifest, PjrtRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in ["model_b1", "model_b2", "model_b4", "conv_tile"] {
        assert!(m.entries.contains_key(name), "{name} missing from manifest");
    }
    assert_eq!(m.entries["model_b1"].in_dims, vec![1, 3, 32, 32]);
    assert_eq!(m.entries["model_b4"].out_dims, vec![4, 10]);
}

#[cfg(feature = "pjrt")]
#[test]
fn load_and_execute_model_b1() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load_artifact(&dir.join("model_b1.hlo.txt")).unwrap();
    let input = vec![0.1f32; 3 * 32 * 32];
    let out = exe.run_f32(&input, &[1, 3, 32, 32]).unwrap();
    assert_eq!(out.len(), 10);
    assert!(out.iter().all(|v| v.is_finite()));
    // Determinism: same input → same logits.
    let out2 = exe.run_f32(&input, &[1, 3, 32, 32]).unwrap();
    assert_eq!(out, out2);
}

#[cfg(feature = "pjrt")]
#[test]
fn batch_consistency_across_artifacts() {
    // The same image must produce the same logits whether it runs through
    // model_b1, model_b2 or model_b4 (proves the batched lowering is just
    // the stacked single-image computation).
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let exec = ModelExecutor::load(&rt, &dir).unwrap();
    let img: Vec<f32> = (0..exec.image_elems)
        .map(|i| ((i % 17) as f32 - 8.0) / 8.0)
        .collect();

    let single = exec.infer(&img, 1).unwrap();
    let mut four = Vec::new();
    for _ in 0..4 {
        four.extend_from_slice(&img);
    }
    let batched = exec.infer(&four, 4).unwrap();
    for b in 0..4 {
        for c in 0..exec.classes {
            let dev = (single[c] - batched[b * exec.classes + c]).abs();
            assert!(dev < 1e-4, "batch {b} class {c}: {dev}");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn executor_chunks_oversized_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let exec = ModelExecutor::load(&rt, &dir).unwrap();
    assert_eq!(exec.max_batch(), 4);
    // 7 images > max artifact batch → chunked internally.
    let imgs: Vec<f32> = (0..7 * exec.image_elems).map(|i| (i as f32).sin()).collect();
    let out = exec.infer(&imgs, 7).unwrap();
    assert_eq!(out.len(), 7 * exec.classes);
    // First image's logits must equal a direct single inference.
    let direct = exec.infer(&imgs[..exec.image_elems], 1).unwrap();
    for c in 0..exec.classes {
        assert!((out[c] - direct[c]).abs() < 1e-4);
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn conv_tile_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load_artifact(&dir.join("conv_tile.hlo.txt")).unwrap();
    let input = vec![0.5f32; 3 * 32 * 32];
    let out = exe.run_f32(&input, &[3, 32, 32]).unwrap();
    assert_eq!(out.len(), 16 * 14 * 14);
    assert!(out.iter().any(|&v| v != 0.0));
}

#[cfg(feature = "pjrt")]
#[test]
fn golden_numerics_cross_language() {
    // The strongest signal in the repo: logits computed by the rust PJRT
    // runtime from the HLO-text artifact must match the JAX oracle path
    // (golden.txt written at AOT time). Guards against constant elision,
    // layout mix-ups and argument mis-wiring across the language boundary.
    let Some(dir) = artifacts_dir() else { return };
    let golden_path = dir.join("golden.txt");
    if !golden_path.exists() {
        eprintln!("skipping: golden.txt missing (re-run `make artifacts`)");
        return;
    }
    let text = std::fs::read_to_string(&golden_path).unwrap();
    let golden: Vec<f32> = text
        .lines()
        .filter(|l| !l.starts_with('#'))
        .flat_map(|l| l.split_whitespace())
        .map(|v| v.parse::<f32>().unwrap())
        .collect();
    assert_eq!(golden.len(), 10);

    let rt = PjrtRuntime::cpu().unwrap();
    let exec = ModelExecutor::load(&rt, &dir).unwrap();
    let img: Vec<f32> = (0..exec.image_elems)
        .map(|i| ((i % 17) as f32 - 8.0) / 8.0)
        .collect();
    let got = exec.infer(&img, 1).unwrap();
    for (c, (&g, &w)) in got.iter().zip(golden.iter()).enumerate() {
        assert!(
            (g - w).abs() < 1e-4,
            "class {c}: rust {g} vs oracle {w}"
        );
    }
}

#[test]
fn missing_artifact_gives_friendly_error() {
    let rt = PjrtRuntime::cpu().unwrap();
    let Err(err) = rt.load_artifact(std::path::Path::new("/nonexistent/nope.hlo.txt")) else {
        panic!("loading a missing artifact must fail");
    };
    let msg = err.to_string();
    assert!(msg.contains("make artifacts"), "{msg}");
}
