//! Determinism of the parallel DSE substrate (`util::par`): search results
//! are bit-identical no matter how many worker threads run — asserted here
//! across widths {1, 2, 8} (and by CI across whole-process
//! `SUPERLIP_THREADS` settings {1, 4}; see `.github/workflows/ci.yml`).
//!
//! The thread count is forced via `util::par::override_threads` rather
//! than by mutating `RAYON_NUM_THREADS`: `setenv` racing `getenv` from
//! concurrent test threads is undefined behavior on glibc.

use superlip::analytic::{Design, XferMode};
use superlip::dse;
use superlip::model::{zoo, ConvLayer, Network};
use superlip::platform::{FpgaSpec, Precision};
use superlip::util::par;

const WIDTHS: [usize; 3] = [1, 2, 8];

fn toy_net() -> Network {
    // Small candidate space, one repeated shape (exercises the dedup).
    let a = ConvLayer::conv("a", 1, 32, 24, 14, 14, 3);
    let b = ConvLayer::conv("b", 1, 48, 16, 7, 7, 5);
    Network::new("toy", vec![a.clone(), b, a])
}

#[test]
fn top_uniform_designs_bit_identical_across_thread_counts() {
    let net = toy_net();
    let fpga = FpgaSpec::zcu102();
    let runs: Vec<_> = WIDTHS
        .iter()
        .map(|&w| {
            let guard = par::override_threads(w);
            let (top, stats, _elapsed) =
                dse::top_uniform_designs(&net, &fpga, Precision::Fixed16, 8);
            drop(guard);
            (top, stats.evaluated, stats.infeasible)
        })
        .collect();
    for (w, run) in WIDTHS.iter().zip(&runs).skip(1) {
        assert_eq!(
            runs[0].0, run.0,
            "top-k must be bit-identical at {w} threads"
        );
        assert_eq!(runs[0].1, run.1, "evaluated count differs at {w} threads");
        assert_eq!(runs[0].2, run.2, "infeasible count differs at {w} threads");
    }
}

#[test]
fn best_factors_bit_identical_across_thread_counts() {
    let net = zoo::alexnet();
    let d = Design::fixed16(128, 10, 7, 14);
    let fpga = FpgaSpec::zcu102();
    for n in [4u64, 8, 16] {
        for mode in [XferMode::Xfer, XferMode::Baseline] {
            let runs: Vec<_> = WIDTHS
                .iter()
                .map(|&w| {
                    let guard = par::override_threads(w);
                    let r = dse::best_factors(&net, &d, &fpga, n, mode);
                    drop(guard);
                    r
                })
                .collect();
            for run in &runs[1..] {
                assert_eq!(runs[0], *run, "n={n} {mode:?}");
            }
        }
    }
}

#[test]
fn best_layer_design_bit_identical_across_thread_counts() {
    let layer = zoo::alexnet().layers[2].clone();
    let fpga = FpgaSpec::zcu102();
    let runs: Vec<_> = WIDTHS
        .iter()
        .map(|&w| {
            let guard = par::override_threads(w);
            let (design, ll, stats) = dse::best_layer_design(&layer, &fpga, Precision::Fixed16);
            drop(guard);
            (design, ll.lat, stats.evaluated, stats.infeasible)
        })
        .collect();
    for run in &runs[1..] {
        assert_eq!(runs[0], *run);
    }
}
