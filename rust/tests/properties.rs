//! Property-based tests (mini-proptest on SplitMix64) over the crate's
//! core invariants: slicing coverage, torus structure, model monotonicity,
//! XFER dominance, simulator envelope, and serving-queue conservation.

use superlip::analytic::{
    check_feasible, layer_latency, xfer_layer_latency, Design, XferMode,
};
use superlip::model::ConvLayer;
use superlip::partition::{slice_layer, Factors, Torus};
use superlip::platform::{FpgaSpec, Precision};
use superlip::sim::{simulate_layer, SimConfig};
use superlip::util::proptest::{forall, forall_shrink};
use superlip::util::SplitMix64;

/// Random conv layer in realistic ranges.
fn gen_layer(r: &mut SplitMix64) -> ConvLayer {
    let k = *r.choose(&[1u64, 3, 5, 7, 11]);
    ConvLayer::strided(
        "prop",
        r.range(1, 4),
        r.range(1, 512),
        r.range(1, 512),
        r.range(1, 56),
        r.range(1, 56),
        k,
        r.range(1, 2),
    )
}

/// Random feasible-ish design.
fn gen_design(r: &mut SplitMix64) -> Design {
    let p = if r.below(2) == 0 {
        Precision::Float32
    } else {
        Precision::Fixed16
    };
    let d = Design {
        tm: r.range(1, 128),
        tn: r.range(1, 64),
        tr: r.range(1, 14),
        tc: r.range(1, 14),
        ip: *r.choose(&[1u64, 2, 4, 8]),
        wp: *r.choose(&[1u64, 2, 4, 8]),
        op: *r.choose(&[1u64, 2, 4, 8]),
        precision: p,
    };
    d
}

fn gen_factors(r: &mut SplitMix64) -> Factors {
    Factors::new(
        *r.choose(&[1u64, 2]),
        *r.choose(&[1u64, 2, 3]),
        *r.choose(&[1u64, 2]),
        *r.choose(&[1u64, 2, 4]),
    )
}

#[test]
fn prop_slices_partition_layer_exactly() {
    forall(
        0xA11CE,
        300,
        |r| (gen_layer(r), gen_factors(r)),
        |(layer, f)| {
            let slices = slice_layer(layer, f);
            slices.len() as u64 == f.num_fpgas()
                && slices.iter().map(|s| s.macs()).sum::<u64>() == layer.macs()
        },
    );
}

#[test]
fn prop_slices_balanced() {
    // No slice exceeds its fair share by more than the ±1-remainder bound.
    forall(
        0xBA1A,
        300,
        |r| (gen_layer(r), gen_factors(r)),
        |(layer, f)| {
            let slices = slice_layer(layer, f);
            let max = slices.iter().map(|s| s.macs()).max().unwrap();
            // Fair share with every partitioned dim rounded up.
            let bound = layer.macs().div_ceil(f.pb)
                / 1
                .max(1);
            // Loose but sound: max slice ≤ ceil in every dimension product.
            let per_dim_bound = (layer.b.div_ceil(f.pb))
                * (layer.r.div_ceil(f.pr))
                * (layer.c.div_ceil(f.pc))
                * (layer.m.div_ceil(f.pm))
                * layer.n_per_group()
                * layer.k
                * layer.k;
            let _ = bound;
            max <= per_dim_bound
        },
    );
}

#[test]
fn prop_latency_monotone_in_ports() {
    // Widening any AXI stream never increases latency (eqs 8–10).
    forall(
        0x9087,
        300,
        |r| (gen_layer(r), gen_design(r)),
        |(layer, d)| {
            let base = layer_latency(layer, d).lat;
            let mut wider = *d;
            wider.ip *= 2;
            wider.wp *= 2;
            wider.op *= 2;
            layer_latency(layer, &wider).lat <= base
        },
    );
}

#[test]
fn prop_latency_covers_compute_lower_bound() {
    // eq 14 ≥ total engine invocations × tComp (no free lunch).
    forall(
        0x10_44,
        300,
        |r| (gen_layer(r), gen_design(r)),
        |(layer, d)| {
            let ll = layer_latency(layer, d);
            ll.lat >= ll.trips_outer * ll.trips_n * ll.t_comp / ll.trips_n.max(1)
        },
    );
}

#[test]
fn prop_xfer_never_slower_than_baseline() {
    let fpga = FpgaSpec::zcu102();
    forall_shrink(
        0xFE12,
        200,
        |r| (gen_layer(r), gen_design(r), gen_factors(r)),
        |(l, d, f)| {
            // Shrink partitions toward single.
            let mut out = Vec::new();
            if f.num_fpgas() > 1 {
                out.push((l.clone(), *d, Factors::single()));
            }
            out
        },
        |(layer, d, f)| {
            let base = xfer_layer_latency(layer, d, f, &fpga, XferMode::Baseline);
            let xfer = xfer_layer_latency(layer, d, f, &fpga, XferMode::Xfer);
            xfer.worst.lat <= base.worst.lat
        },
    );
}

#[test]
fn prop_simulator_envelope() {
    // The simulator only ADDS real-world cost (sync + DDR burst setup +
    // contention), and that cost is linear in the number of pipeline
    // phases — never super-linear.
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    forall(
        0x51AB,
        200,
        |r| (gen_layer(r), gen_design(r)),
        |(layer, d)| {
            let ll = layer_latency(layer, d);
            let sim = simulate_layer(layer, d, &cfg).cycles;
            // Per inner phase: one sync + (possibly contended) setups on
            // two concurrent streams; per outer phase: one OFM setup+sync;
            // plus prologue/epilogue. Contention multiplies ≤ 2× here.
            let phases = ll.trips_outer * ll.trips_n + ll.trips_outer + 2;
            let per_phase = cfg.sync_cycles + 2 * (2 * cfg.ddr_tile_setup) + cfg.link_setup;
            sim >= ll.lat && sim - ll.lat <= phases * per_phase + ll.lat / 2
        },
    );
}

#[test]
fn prop_torus_ring_delivers_all_chunks() {
    forall(0x7085, 100, |r| r.range(2, 12), |&p| {
        let steps = Torus::ring_schedule(p);
        let mut own: Vec<Vec<bool>> = (0..p)
            .map(|i| (0..p).map(|c| c == i).collect())
            .collect();
        for step in &steps {
            let snap = own.clone();
            for &(from, to, chunk) in step {
                if !snap[from as usize][chunk as usize] {
                    return false;
                }
                own[to as usize][chunk as usize] = true;
            }
        }
        own.iter().all(|h| h.iter().all(|&x| x))
    });
}

#[test]
fn prop_torus_shape_matches_factors() {
    forall(0x2D, 200, |r| gen_factors(r), |f| {
        let t = Torus::for_factors(f);
        t.num_nodes() == f.num_fpgas()
            && t.rows == f.weight_share()
            && t.cols == f.ifm_share()
            && t.out_degree() <= 2
    });
}

#[test]
fn prop_resource_check_consistent() {
    // If a design passes eqs 1–7 at kernel K, it passes at any K' ≤ K.
    let fpga = FpgaSpec::zcu102();
    forall(
        0xC0DE,
        300,
        |r| (gen_design(r), r.range(1, 11)),
        |(d, k)| {
            if check_feasible(d, &fpga, *k).is_ok() {
                (1..=*k).all(|k2| check_feasible(d, &fpga, k2).is_ok())
            } else {
                true
            }
        },
    );
}

#[test]
fn prop_fx16_quantization_error_bounded() {
    use superlip::util::{dequantize_fx16, quantize_fx16, FX16_FRAC_BITS};
    forall(0x0F16, 1000, |r| (r.f64() * 200.0 - 100.0) as f32, |&x| {
        let err = (dequantize_fx16(quantize_fx16(x)) - x).abs();
        err <= 0.5 / (1u32 << FX16_FRAC_BITS) as f32 + 1e-6
    });
}
