//! Figure 2: design-space exploration of AlexNet conv5 under the [14]
//! roofline model vs real (simulated) performance — shows attainable-looking
//! designs that miss their predicted performance, and that the [14]-optimal
//! design is not the truly optimal one.

use superlip::analytic::{Design, XferMode};
use superlip::bench::Harness;
use superlip::dse::roofline_scatter;
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::{FpgaSpec, Precision};
use superlip::report::Table;
use superlip::sim::{simulate_layer, SimConfig};

fn main() {
    let mut h = Harness::new("fig2_roofline");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = zoo::alexnet();
    let conv5 = net.layers[4].clone();

    let mut pts = Vec::new();
    h.measure("enumerate roofline scatter (conv5, f32)", || {
        pts = roofline_scatter(&conv5, &fpga, Precision::Float32);
    });
    h.record("scatter points", pts.len() as f64, "designs");

    // "Real" performance for every point, via the simulator.
    let real_gops = |d: &Design| {
        let cycles = simulate_layer(&conv5, d, &cfg).cycles;
        conv5.ops() as f64 / Precision::Float32.cycles_to_s(cycles) / 1e9
    };

    // Design A: best under the [14] roofline. Design B: best real.
    let a = pts
        .iter()
        .max_by(|x, y| x.roofline_gops.total_cmp(&y.roofline_gops))
        .unwrap();
    let b = pts
        .iter()
        .max_by(|x, y| real_gops(&x.design).total_cmp(&real_gops(&y.design)))
        .unwrap();

    let mut t = Table::new(&["Point", "Design", "CTC", "[14] GOPS", "Real GOPS", "Gap"]);
    for (label, p) in [("A (best-by-[14])", a), ("B (best-real)", b)] {
        let real = real_gops(&p.design);
        t.row(&[
            label.into(),
            format!("<{},{}>", p.design.tm, p.design.tn),
            format!("{:.1}", p.ctc),
            format!("{:.1}", p.roofline_gops),
            format!("{real:.1}"),
            format!("{:.1}%", (1.0 - real / p.roofline_gops) * 100.0),
        ]);
    }
    h.table("Figure 2: model-vs-real for designs A and B", &t.render());

    let real_a = real_gops(&a.design);
    let real_b = real_gops(&b.design);
    h.record("A real/model ratio", real_a / a.roofline_gops, "");
    h.record("B real/A real", real_b / real_a, "");
    println!(
        "  paper shape: A,B below their model points; B beats A in reality — {}",
        if real_b >= real_a { "REPRODUCED" } else { "NOT reproduced" }
    );

    // Sanity: the 2-FPGA planner can still use conv5's best design.
    let _ = Factors::single();
    let _ = XferMode::Xfer;
    h.finish();
}
