//! Figure 15: design-space exploration with growing cluster size (1–16
//! FPGAs) for AlexNet, SqueezeNet, VGG16 and YOLO — latency must fall
//! monotonically; AlexNet/VGG/YOLO reach super-linear speedups while
//! SqueezeNet (compute-bound 1×1 convs) stays sub-linear; energy
//! efficiency improves vs single-FPGA.

use superlip::analytic::{check_feasible, Design, XferMode};
use superlip::bench::Harness;
use superlip::dse;
use superlip::energy::{self, PowerModel};
use superlip::model::zoo;
use superlip::platform::FpgaSpec;
use superlip::report::{self, ascii_plot, Table};
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let mut h = Harness::new("fig15_scaling");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let sizes = [1u64, 2, 3, 4, 6, 8, 9, 12, 16];

    let tilings = [
        ("AlexNet", Design::fixed16(128, 10, 7, 14), 17.95),
        ("SqueezeNet", Design::fixed16(64, 16, 7, 14), 14.75),
        ("VGG16", Design::fixed16(64, 25, 7, 14), f64::NAN),
        ("YOLO", Design::fixed16(64, 25, 7, 14), 27.93),
    ];

    let mut series = Vec::new();
    for (name, d, paper_16) in tilings {
        let net = zoo::by_name(name).unwrap();
        let k_max = net.conv_layers().map(|l| l.k).max().unwrap();
        let usage = check_feasible(&d, &fpga, k_max).expect("tiling feasible");
        let total_ops: u64 = net.conv_layers().map(|l| l.ops()).sum();

        let mut t = Table::new(&["FPGAs", "Partition", "ms", "Speedup", "EE(GOPS/W)"]);
        let mut csv_rows: Vec<Vec<String>> = Vec::new();
        let mut single = 0u64;
        let mut pts = Vec::new();
        let mut speedups = Vec::new();
        for &n in &sizes {
            let (f, _) = dse::best_factors(&net, &d, &fpga, n, XferMode::Xfer);
            let sim = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer);
            if n == 1 {
                single = sim.cycles;
            }
            let ms = d.precision.cycles_to_ms(sim.cycles);
            let speedup = single as f64 / sim.cycles as f64;
            speedups.push((n, speedup));
            let gops = energy::gops(total_ops, sim.cycles, d.precision);
            let ee = gops / PowerModel::new(n).watts(&d, &usage);
            t.row(&[
                n.to_string(),
                f.to_string(),
                report::ms(ms),
                report::speedup(speedup),
                format!("{ee:.2}"),
            ]);
            csv_rows.push(vec![
                n.to_string(),
                f.to_string(),
                format!("{}", sim.cycles),
                format!("{ms:.4}"),
                format!("{speedup:.4}"),
                format!("{ee:.4}"),
            ]);
            pts.push((n as f64, ms));
        }
        h.table(&format!("Figure 15: {name} (design {d})"), &t.render());
        // Machine-readable series for re-plotting.
        let csv = report::write_csv(
            std::path::Path::new("results"),
            &format!("fig15_{}", name.to_lowercase()),
            &["fpgas", "partition", "cycles", "ms", "speedup", "gops_per_watt"],
            &csv_rows,
        )
        .expect("write results csv");
        println!("  wrote {}", csv.display());
        let s16 = speedups.last().unwrap().1;
        h.record(
            &format!("{name} 16-FPGA speedup (SFP+ 256b)"),
            s16,
            &format!("x (paper: {paper_16})"),
        );
        // §5E link upgrade: 4 extra QSFP ports (1024 bits/cycle) keep the
        // rings off the critical path at 16 FPGAs — the paper's large-
        // cluster numbers implicitly assume this headroom.
        {
            let qsfp = superlip::platform::FpgaSpec::zcu102_qsfp();
            let (f, _) = dse::best_factors(&net, &d, &qsfp, 16, XferMode::Xfer);
            let sim = simulate_network(&net, &d, &f, &qsfp, &cfg, XferMode::Xfer);
            h.record(
                &format!("{name} 16-FPGA speedup (QSFP 1024b)"),
                single as f64 / sim.cycles as f64,
                &format!("x (paper: {paper_16})"),
            );
        }
        let s2 = speedups[1].1;
        let s4 = speedups[3].1;
        println!(
            "  {name}: 2-FPGA {:.2}x, 4-FPGA {:.2}x — super-linear at small scale: {}",
            s2,
            s4,
            if name == "SqueezeNet" {
                if s2 <= 2.3 { "correctly NOT (compute-bound)" } else { "unexpectedly yes" }
            } else if s2 > 2.0 {
                "REPRODUCED"
            } else {
                "NOT reproduced"
            }
        );
        series.push((name.to_string(), pts));
    }
    println!("\n{}", ascii_plot("latency vs cluster size (ms)", &series, 8));

    let net = zoo::yolov1();
    let d = Design::fixed16(64, 25, 7, 14);
    h.measure("YOLO 16-FPGA partition search + sim", || {
        let (f, _) = dse::best_factors(&net, &d, &fpga, 16, XferMode::Xfer);
        std::hint::black_box(simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer));
    });
    h.finish();
}
