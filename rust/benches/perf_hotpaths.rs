//! §Perf: the L3 hot paths — analytic-model evaluation, cluster
//! simulation, DSE, and the serving fast path (batcher throughput).
//! Baselines and targets live in EXPERIMENTS.md §Perf.
//!
//! The XFER/partition measurements print BOTH the closed-form fast path
//! and the retained naive reference (`*_ref`), so before/after speedups
//! come from one run on one machine. Set `RAYON_NUM_THREADS=1` for
//! deterministic single-core timing runs.

use std::sync::mpsc;
use std::time::{Duration, Instant};
use superlip::analytic::{
    layer_latency, network_latency, xfer_layer_latency, xfer_layer_latency_ref, Design, XferMode,
};
use superlip::bench::Harness;
use superlip::dse;
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::{FpgaSpec, Precision};
use superlip::serving::{Batcher, BatcherConfig, InferenceRequest};
use superlip::sim::{simulate_network, SimConfig};
use superlip::util::par;

fn main() {
    let mut h = Harness::new("perf_hotpaths");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let alexnet = zoo::alexnet();
    let vgg = zoo::vgg16();
    // Hoisted out of every measured closure: network construction is not
    // part of any hot path being measured.
    let yolo = zoo::yolov1();
    let d = Design::fixed16(128, 10, 7, 14);
    h.record("worker threads (RAYON_NUM_THREADS)", par::num_threads() as f64, "threads");

    // --- Analytic model evaluation rate (the DSE inner loop).
    let conv3 = alexnet.layers[2].clone();
    let t0 = Instant::now();
    let n_eval = if h.is_quick() { 100_000u64 } else { 2_000_000u64 };
    let mut acc = 0u64;
    for i in 0..n_eval {
        let dd = Design::fixed16(1 + (i % 128), 1 + (i % 24), 7, 14);
        acc = acc.wrapping_add(layer_latency(&conv3, &dd).lat);
    }
    let rate = n_eval as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    h.record("analytic model eval rate", rate / 1e6, "M evals/s");

    // --- XFER cluster-model evaluation rate: closed-form corners vs the
    // naive slice-materializing reference (the tentpole's core win).
    let f16 = Factors::new(1, 4, 1, 4);
    let n_xfer = if h.is_quick() { 2_000u64 } else { 50_000u64 };
    let t0 = Instant::now();
    let mut acc = 0u64;
    for i in 0..n_xfer {
        let dd = Design::fixed16(1 + (i % 128), 1 + (i % 24), 7, 14);
        let r = xfer_layer_latency(&conv3, &dd, &f16, &fpga, XferMode::Xfer);
        acc = acc.wrapping_add(r.worst.lat);
    }
    let fast = n_xfer as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    let t0 = Instant::now();
    let mut acc = 0u64;
    for i in 0..n_xfer {
        let dd = Design::fixed16(1 + (i % 128), 1 + (i % 24), 7, 14);
        let r = xfer_layer_latency_ref(&conv3, &dd, &f16, &fpga, XferMode::Xfer);
        acc = acc.wrapping_add(r.worst.lat);
    }
    let naive = n_xfer as f64 / t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    h.record("xfer eval rate (closed-form, 16 FPGAs)", fast / 1e6, "M evals/s");
    h.record("xfer eval rate (naive ref, 16 FPGAs)", naive / 1e6, "M evals/s");
    h.record("xfer eval speedup (fast/ref)", fast / naive, "x");

    h.measure("network_latency AlexNet", || {
        std::hint::black_box(network_latency(&alexnet, &d));
    });

    // --- Cluster simulation throughput.
    h.measure("simulate AlexNet 1 FPGA", || {
        std::hint::black_box(simulate_network(
            &alexnet,
            &d,
            &Factors::single(),
            &fpga,
            &cfg,
            XferMode::Xfer,
        ));
    });
    h.measure("simulate VGG16 16-FPGA XFER", || {
        std::hint::black_box(simulate_network(
            &vgg,
            &Design::fixed16(64, 25, 7, 14),
            &Factors::new(1, 4, 1, 4),
            &fpga,
            &cfg,
            XferMode::Xfer,
        ));
    });

    // --- DSE end-to-end (the paper's "3 min/layer" / "13 min cross-layer").
    h.measure("per-layer DSE (AlexNet conv3, fx16)", || {
        std::hint::black_box(dse::best_layer_design(&conv3, &fpga, Precision::Fixed16));
    });
    h.measure("cross-layer DSE (AlexNet, fx16)", || {
        std::hint::black_box(dse::best_uniform_design(&alexnet, &fpga, Precision::Fixed16));
    });
    h.measure("partition search (YOLO, 16 FPGAs)", || {
        std::hint::black_box(dse::best_factors(
            &yolo,
            &Design::fixed16(64, 25, 7, 14),
            &fpga,
            16,
            XferMode::Xfer,
        ));
    });

    // --- Serving fast path: batcher push/pop throughput (no compute).
    // Channel construction is NOT part of the batcher hot path — build all
    // reply channels before starting the clock.
    let n_req = 20_000usize;
    let b = Batcher::new(BatcherConfig {
        max_batch: 4,
        window: Duration::from_micros(0),
        deadline_margin: Duration::from_micros(0),
        ..BatcherConfig::default()
    });
    let now = Instant::now();
    let mut chans = Vec::with_capacity(n_req);
    let mut keep = Vec::with_capacity(n_req);
    for _ in 0..n_req {
        let (tx, rx) = mpsc::channel();
        chans.push(tx);
        keep.push(rx);
    }
    let t0 = Instant::now();
    let mut popped = 0usize;
    for (i, tx) in chans.into_iter().enumerate() {
        b.push(InferenceRequest {
            id: i as u64,
            image: Vec::new(),
            enqueued: now,
            deadline: now + Duration::from_secs(3600),
            class: superlip::fleet::SloClass::BestEffort,
            trace: Default::default(),
            reply: tx,
        })
        .unwrap();
        if i % 4 == 3 {
            popped += b.next_batch().unwrap().len();
        }
    }
    while popped < n_req {
        popped += b.next_batch().unwrap().len();
    }
    let rps = n_req as f64 / t0.elapsed().as_secs_f64();
    h.record("batcher push+batch rate", rps / 1e6, "M req/s");

    h.finish();
}
