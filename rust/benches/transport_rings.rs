//! Transport-layer microbench (EXPERIMENTS.md §Transport): price the
//! queue-pair machinery itself — submit/completion rings, doorbells,
//! pooled zero-copy buffers, FNV checksums, and the shim device thread —
//! with the link model at zero latency / infinite bandwidth so every
//! measured nanosecond is transport overhead, not modeled wire time.
//!
//! Two sections:
//!
//! * **qp echo** — a single client drives one `TransportBackend` closed
//!   loop (submit to pipeline depth, reap, refill) against a null device.
//!   `ns/req` here is the per-descriptor round trip through both rings.
//! * **shim-lane hot path** — the serving_hotpath bench shape (3
//!   submitters, 2 lanes × 2 workers, LeastOutstanding routing), but with
//!   every worker's backend behind `shim_factory`. Comparing its `ns/req`
//!   against BENCH_serving.json prices the whole transport detour under
//!   real batching; the acceptance envelope is ≤25% over the direct path.
//!
//! Gated metrics (`ns/req`, `rps/core`) land in BENCH_transport.json; the
//! mean in-flight descriptor depth is recorded informationally in `desc`
//! units — it proves the pipelining actually overlaps, but it is
//! scheduler-sensitive and must never gate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};
use superlip::bench::Harness;
use superlip::fleet::SloClass;
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, PipelinedBackend, RoutePolicy, Server,
    ServerConfig,
};
use superlip::transport::{TransportBackend, TransportConfig};

/// One scalar in, one logit out, no work — same null device as the
/// serving_hotpath baseline so the delta is pure transport.
struct NullBackend;

impl InferBackend for NullBackend {
    fn image_elems(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn infer(&self, _images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        Ok(vec![0.0; n])
    }
}

fn null_factory() -> BackendFactory {
    Box::new(|| Ok(Box::new(NullBackend) as Box<dyn InferBackend>))
}

/// Ideal-link transport: every nanosecond measured is ring machinery.
fn transport_cfg() -> TransportConfig {
    TransportConfig {
        ring_capacity: 32,
        pipeline_depth: 8,
        ..TransportConfig::default()
    }
}

/// Closed-loop echo through one queue pair: keep `depth` descriptors in
/// flight, reap, refill. Returns (completions, wall secs, mean in-flight).
fn qp_echo(n_total: usize) -> (u64, f64, f64) {
    let tb = TransportBackend::over_shim(transport_cfg(), null_factory()).expect("shim bring-up");
    let depth = tb.depth();
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut fill = |dst: &mut [f32]| dst.fill(0.0);
    let mut submitted = 0usize;
    let mut reaped = 0u64;
    let mut inflight_samples = 0u64;
    let mut inflight_sum = 0u64;
    let t0 = Instant::now();
    while (reaped as usize) < n_total {
        while submitted < n_total && tb.in_flight() < depth {
            if tb.submit_batch(1, deadline, &mut fill).is_err() {
                break; // typed backpressure: reap below, then refill
            }
            submitted += 1;
        }
        inflight_sum += tb.in_flight() as u64;
        inflight_samples += 1;
        reaped += tb.reap_batches(Duration::from_micros(200)).len() as u64;
    }
    let wall = t0.elapsed().as_secs_f64();
    let mean_inflight = inflight_sum as f64 / inflight_samples.max(1) as f64;
    (reaped, wall, mean_inflight)
}

const MODEL: &str = "null";
const LANES: usize = 2;
const WORKERS_PER_LANE: usize = 2;
const SUBMITTERS: usize = 3;
const PIPELINE: usize = 64;

fn shim_lane() -> LaneSpec {
    LaneSpec {
        model: MODEL.into(),
        factories: (0..WORKERS_PER_LANE)
            .map(|_| TransportBackend::shim_factory(transport_cfg(), null_factory()))
            .collect(),
        batcher: BatcherConfig {
            max_batch: 32,
            window: Duration::from_millis(0),
            ..BatcherConfig::default()
        },
    }
}

/// The serving_hotpath closed loop, verbatim shape: bounded in-flight
/// window per submitter so the pipeline saturates without queue blowup.
fn drive(server: &Server, per_submitter: usize) -> (u64, f64) {
    let completed = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..SUBMITTERS {
            let completed = &completed;
            s.spawn(move || {
                let deadline = Duration::from_secs(5);
                let class = match t % 3 {
                    0 => SloClass::Gold,
                    1 => SloClass::Silver,
                    _ => SloClass::BestEffort,
                };
                let mut inflight = std::collections::VecDeque::with_capacity(PIPELINE);
                let mut done = 0u64;
                for _ in 0..per_submitter {
                    let rx = server
                        .submit_to_class(MODEL, vec![0.0], deadline, class)
                        .expect("shim lane accepts");
                    inflight.push_back(rx);
                    if inflight.len() >= PIPELINE {
                        let oldest = inflight.pop_front().unwrap();
                        oldest.recv().expect("response");
                        done += 1;
                    }
                }
                for rx in inflight {
                    rx.recv().expect("response");
                    done += 1;
                }
                completed.fetch_add(done, Ordering::Relaxed);
            });
        }
    });
    (completed.load(Ordering::Relaxed), t0.elapsed().as_secs_f64())
}

fn main() {
    let mut h = Harness::new("transport_rings");
    let n_echo: usize = if h.is_quick() { 20_000 } else { 200_000 };
    let per_submitter: usize = if h.is_quick() { 10_000 } else { 100_000 };

    // §1: raw queue-pair round trip, no serving machinery above it.
    qp_echo(n_echo / 10); // warmup
    let (n, wall, mean_inflight) = qp_echo(n_echo);
    assert_eq!(n as usize, n_echo, "every descriptor reaped exactly once");
    h.record("qp echo, submit→reap", wall * 1e9 / n as f64, "ns/req");
    h.record("qp echo mean in-flight", mean_inflight, "desc");

    // §2: the full serving hot path with the transport under every lane.
    let server = Server::start_plan(
        (0..LANES).map(|_| shim_lane()).collect(),
        ServerConfig {
            policy: RoutePolicy::LeastOutstanding,
            ..ServerConfig::default()
        },
    );
    drive(&server, per_submitter / 10); // warmup
    server.metrics().reset();
    let (n, wall) = drive(&server, per_submitter);
    assert_eq!(n as usize, SUBMITTERS * per_submitter, "exactly-one-response");

    let throughput = n as f64 / wall;
    // Honest core count: the shim moved the (null) inference onto device
    // threads, so they join the denominator alongside submitters + workers.
    let cores = (SUBMITTERS + 2 * LANES * WORKERS_PER_LANE) as f64;
    h.record("shim-lane hot path, submit→complete", wall * 1e9 / n as f64, "ns/req");
    h.record("shim-lane throughput per core", throughput / cores, "rps/core");
    h.record("shim-lane aggregate throughput", throughput, "req/s");
    h.record("mean batch", server.metrics().mean_batch(), "req");

    server.shutdown();
    h.finish();
}
