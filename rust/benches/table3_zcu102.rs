//! Table 3: FPGA15 (re-implemented on one ZCU102) vs Super-LIP (2 ZCU102s),
//! per AlexNet conv layer, for both precisions — the 2.25× (f32) and 3.48×
//! (fx16) speedups and the energy-efficiency improvements.

use superlip::analytic::{check_feasible, Design, XferMode};
use superlip::bench::Harness;
use superlip::dse;
use superlip::energy::{self, PowerModel};
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};
use superlip::sim::{simulate_cluster, simulate_network, SimConfig};

fn main() {
    let mut h = Harness::new("table3_zcu102");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = zoo::alexnet();
    let total_ops: u64 = net.conv_layers().map(|l| l.ops()).sum();

    // (precision label, FPGA15 design, Super-LIP design)
    let setups = [
        (
            "32bits float",
            Design::float32(64, 7, 7, 14),
            Design::float32(64, 7, 7, 14),
        ),
        (
            "16bits fixed",
            Design::fixed16(64, 24, 7, 14),
            Design::fixed16(128, 10, 7, 14),
        ),
    ];

    for (plabel, d_single, d_dual) in setups {
        let (f2, _) = dse::best_factors(&net, &d_dual, &fpga, 2, XferMode::Xfer);
        let mut t = Table::new(&[
            "Layer", "FPGA15 ms", "FPGA15 GOPS", "SuperLIP ms", "SuperLIP GOPS",
        ]);
        let mut tot1 = 0u64;
        let mut tot2 = 0u64;
        for l in net.conv_layers() {
            let (s1, _) =
                simulate_cluster(l, &d_single, &Factors::single(), &fpga, &cfg, XferMode::Xfer);
            let (s2, _) = simulate_cluster(l, &d_dual, &f2, &fpga, &cfg, XferMode::Xfer);
            tot1 += s1.cycles;
            tot2 += s2.cycles;
            t.row(&[
                l.name.clone(),
                report::ms(d_single.precision.cycles_to_ms(s1.cycles)),
                report::gops(energy::gops(l.ops(), s1.cycles, d_single.precision)),
                report::ms(d_dual.precision.cycles_to_ms(s2.cycles)),
                report::gops(energy::gops(l.ops(), s2.cycles, d_dual.precision)),
            ]);
        }
        let sim1 = simulate_network(&net, &d_single, &Factors::single(), &fpga, &cfg, XferMode::Xfer);
        let sim2 = simulate_network(&net, &d_dual, &f2, &fpga, &cfg, XferMode::Xfer);
        t.row(&[
            "overall".into(),
            report::ms(d_single.precision.cycles_to_ms(sim1.cycles)),
            report::gops(energy::gops(total_ops, sim1.cycles, d_single.precision)),
            report::ms(d_dual.precision.cycles_to_ms(sim2.cycles)),
            report::gops(energy::gops(total_ops, sim2.cycles, d_dual.precision)),
        ]);
        h.table(&format!("Table 3 ({plabel})"), &t.render());

        let k_max = net.conv_layers().map(|l| l.k).max().unwrap();
        let u1 = check_feasible(&d_single, &fpga, k_max).unwrap();
        let u2 = check_feasible(&d_dual, &fpga, k_max).unwrap();
        let g1 = energy::gops(total_ops, sim1.cycles, d_single.precision);
        let g2 = energy::gops(total_ops, sim2.cycles, d_dual.precision);
        let ee1 = g1 / PowerModel::new(1).watts(&d_single, &u1);
        let ee2 = g2 / PowerModel::new(2).watts(&d_dual, &u2);
        let speedup = sim1.cycles as f64 * d_dual.precision.freq_mhz() as f64
            / (sim2.cycles as f64 * d_single.precision.freq_mhz() as f64);
        h.record(
            &format!("{plabel}: speedup"),
            speedup,
            "x (paper: 2.25x f32 / 3.48x fx16)",
        );
        h.record(
            &format!("{plabel}: EE improvement"),
            (ee2 / ee1 - 1.0) * 100.0,
            "% (paper: 9.21% f32 / 39.86% fx16)",
        );
        println!(
            "  super-linear (>2x on 2 FPGAs): {}",
            if speedup > 2.0 { "REPRODUCED" } else { "NOT reproduced" }
        );
        assert!(tot1 > 0 && tot2 > 0);
    }

    let d = Design::fixed16(128, 10, 7, 14);
    h.measure("per-layer cluster sim (fx16, 2 FPGAs)", || {
        let (f2, _) = dse::best_factors(&net, &d, &fpga, 2, XferMode::Xfer);
        for l in net.conv_layers() {
            std::hint::black_box(simulate_cluster(l, &d, &f2, &fpga, &cfg, XferMode::Xfer));
        }
    });
    h.finish();
}
