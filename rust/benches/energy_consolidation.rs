//! Energy-aware elastic consolidation (EXPERIMENTS.md §Energy): a hot
//! model cools off mid-run, and the power-managed controller must cut
//! fleet average watts ≥ 20% vs the static plan — with no deadline-miss
//! regression, exactly one response per request, and zero requests routed
//! to a non-Active board — then scale back out through a board wake when
//! the traffic returns.
//!
//! Self-calibrated three-phase scenario on a 4-board fleet:
//!
//! * **hot** — alexnet at 0.55 of its 3-board service rate (the
//!   `control_drift` operating point) + a cold squeezenet;
//! * **cool** — alexnet collapses to 15% of its 1-board rate. The drift
//!   detector's expected-arrivals collapse trigger fires (observed
//!   arrivals alone could never gate a silent stream), the re-planner's
//!   energy objective consolidates both models onto one board each, and
//!   the controller powers the freed boards down. The static plan burns
//!   idle watts on every board forever;
//! * **re-warm** — alexnet returns to the hot rate. The controller must
//!   wake a powered-off board BEFORE routing to it (the old lane keeps
//!   serving through the wake — make-before-break absorbs the latency).
//!
//! The watts ledger integrates planned power (idle + dynamic + B2B per
//! §5C) over the run; the acceptance contrast is the cool phase's fleet
//! average.

use std::time::Duration;
use superlip::bench::Harness;
use superlip::control::{run_drift_scenario, ControlConfig, DriftConfig, OnlineConfig, PowerGating};
use superlip::fleet::{stats_table, FleetSpec, PhaseSpec, Planner, PlannerConfig, WorkloadSpec};
use superlip::platform::FpgaSpec;
use superlip::power;
use superlip::report;

const FLEET_SIZE: usize = 4;

fn main() {
    let mut h = Harness::new("energy_consolidation");
    let fleet = FleetSpec::homogeneous(FLEET_SIZE, FpgaSpec::zcu102());
    let pcfg = PlannerConfig::default();
    let planner = Planner::new(fleet.clone(), pcfg);

    let probe = |model: &str, n: usize| planner.service_ms(model, n).expect("probe") / 1e3;
    let (a1, a3) = (probe("alexnet", 1), probe("alexnet", 3));
    let q1 = probe("squeezenet", 1);
    let hot = 0.55 / a3;
    // 15% of the 1-board rate: low enough that one board serves it at
    // ρ ≈ 0.15 (the consolidation verdict is identical down to 0.05),
    // high enough that the cool phase has ~25 samples — a single
    // wall-jitter straggler then moves the miss rate by ~4 pp, not 12.
    let trickle = 0.15 / a1;
    let cold = 0.25 / q1;
    let mix = vec![
        WorkloadSpec::new("alexnet", hot, Duration::from_secs_f64(6.0 * a1)),
        WorkloadSpec::new("squeezenet", cold, Duration::from_secs_f64(6.0 * q1)),
    ];
    println!(
        "  calibration: alexnet s1 {} s3 {} (hot {hot:.0} rps, trickle {trickle:.1} rps), squeezenet s1 {}",
        report::ms(a1 * 1e3),
        report::ms(a3 * 1e3),
        report::ms(q1 * 1e3)
    );

    // tick 0.1 s → the hot stream expects ~28 arrivals per window, well
    // over the collapse trigger's expected-arrivals floor (12), while the
    // Monte-Carlo spurious-fire rate at that level is < 1e-3 per run.
    let tick_s = 0.1;
    let (hot_s, cool_s, rewarm_s) = if h.is_quick() {
        (0.6, 1.0, 0.6)
    } else {
        (1.0, 1.5, 0.8)
    };
    let phases = vec![
        PhaseSpec {
            duration_s: hot_s,
            rates_rps: vec![hot, cold],
        },
        PhaseSpec {
            duration_s: cool_s,
            rates_rps: vec![trickle, cold],
        },
        PhaseSpec {
            duration_s: rewarm_s,
            rates_rps: vec![hot, cold],
        },
    ];
    let cfg = OnlineConfig {
        seed: 2026,
        time_scale: 0.5,
        tick_s,
        power: Some(PowerGating { wake_latency_s: 0.1 }),
        recv_timeout: Duration::from_secs(60),
        control: ControlConfig {
            drift: DriftConfig {
                min_arrivals: 15,
                hysteresis: 3,
                ..DriftConfig::default()
            },
            ..ControlConfig::default()
        },
        ..OnlineConfig::default()
    };
    let plan = planner.plan(&mix).expect("plan");
    h.table("initial plan (hot mix)", &plan.summary());
    h.table("initial power budget", &power::plan_power(&plan).summary());

    let run = |label: &str, controlled: bool, h: &mut Harness| {
        let out = run_drift_scenario(&fleet, pcfg, &mix, &phases, &cfg, controlled)
            .expect("scenario");
        for (pi, rows) in out.phase_stats.iter().enumerate() {
            h.table(
                &format!("{label} — phase {pi} ({:.1} W fleet avg)", out.avg_watts[pi]),
                &stats_table(rows),
            );
        }
        for e in &out.events {
            println!("    [control] {e}");
        }
        out
    };
    let stat = run("static plan (always-on)", false, &mut h);
    let ctl = run("controlled (elastic consolidation)", true, &mut h);

    let (sw, cw) = (stat.avg_watts[1], ctl.avg_watts[1]);
    let saved = (1.0 - cw / sw) * 100.0;
    // Deadline-normalized worst p99 (fraction of each model's deadline) —
    // consolidation trades unused speed for watts, so raw ms on the
    // consolidated model may grow while every deadline still clears; the
    // regression contract is on deadlines, not on idle speed.
    let norm_p99 = |rows: &[superlip::fleet::ModelStats]| -> f64 {
        rows.iter()
            .zip(&mix)
            .map(|(r, w)| r.p99_ms / w.deadline_ms())
            .fold(f64::NAN, f64::max)
    };
    let (sp, cp) = (norm_p99(&stat.phase_stats[1]), norm_p99(&ctl.phase_stats[1]));
    let (sm, cm) = (stat.worst_miss_rate(1), ctl.worst_miss_rate(1));
    let j_per_inf = {
        let done: usize = ctl
            .phase_stats
            .iter()
            .flat_map(|rows| rows.iter().map(|r| r.completed))
            .sum();
        ctl.fleet_joules / done.max(1) as f64
    };

    h.record("cool-phase fleet watts, static", sw, "W");
    h.record("cool-phase fleet watts, controlled", cw, "W");
    h.record("watts saved by consolidation", saved, "");
    h.record("cool-phase worst miss, controlled", cm * 100.0, "%");
    h.record("cool-phase norm p99, controlled", cp * 100.0, "");
    h.record("J per inference, controlled", j_per_inf, "J/inf");
    h.record("re-plans", ctl.replans as f64, "");
    h.record("boards powered off at end", ctl.powered_off as f64, "");
    println!(
        "  consolidation cuts cool-phase watts {saved:.0}% ({sw:.1} → {cw:.1} W); \
         norm p99 {sp:.2} → {cp:.2}, miss {:.1}% → {:.1}%",
        sm * 100.0,
        cm * 100.0
    );

    // Acceptance (ISSUE 5): ≥20% fleet watts cut on the cooled phase...
    assert!(
        cw <= 0.8 * sw,
        "consolidation must cut ≥20% of fleet watts: static {sw:.1} W vs controlled {cw:.1} W"
    );
    // ...with no deadline regression (both runs must clear deadlines
    // comfortably; squeezenet — identical in both — dominates the norm).
    // Slack of ~one straggler on the ~25-request trickle stream; the
    // BENCH_energy.json gate and the norm-p99 bound carry the tighter
    // trajectory contract.
    assert!(
        cm <= sm + 0.05,
        "no miss regression: controlled {:.1}% vs static {:.1}%",
        cm * 100.0,
        sm * 100.0
    );
    assert!(
        cp < 0.7,
        "cool-phase p99 must clear every deadline with headroom (norm {cp:.2})"
    );
    // The controller consolidated AND re-expanded (2 re-plans; tolerate a
    // spurious detector fire or two).
    assert!(
        (2..=4).contains(&ctl.replans),
        "expected consolidate + re-warm re-plans, got {} ({:?})",
        ctl.replans,
        ctl.events
    );
    assert!(
        ctl.events.iter().any(|e| e.contains("powered down boards")),
        "consolidation must power boards down: {:?}",
        ctl.events
    );
    assert!(
        ctl.events.iter().any(|e| e.contains("waking boards")),
        "the re-warm must wake boards before routing: {:?}",
        ctl.events
    );
    assert!(
        ctl.powered_off >= 1,
        "the re-warmed plan still leaves surplus boards off ({} off)",
        ctl.powered_off
    );
    // Exactly one response per request across both migrations (nothing
    // was killed — a dropped or double response would break the counts).
    for rows in &ctl.phase_stats {
        for r in rows {
            assert_eq!(
                r.completed, r.sent,
                "{}: every request gets exactly one response across consolidation",
                r.model
            );
        }
    }
    // And not one batch was served by a non-Active board.
    assert_eq!(
        ctl.power_violations, 0,
        "no request is ever routed to a non-Active board"
    );
    h.finish();
}
