//! Fleet serving scenarios: mixed-model traffic on a shared multi-FPGA
//! cluster (EXPERIMENTS.md §Fleet).
//!
//! An 8-board ZCU102 fleet serves a 4-model mix (AlexNet + SqueezeNet
//! light/interactive, VGG16 + YOLO heavy/deadline-tight). The mix is
//! **self-calibrated** from the simulator so the comparison is robust on
//! any machine: light models get a deadline of 4× their 1-board service
//! time, heavy models a deadline strictly between their 3-board and
//! 2-board service times — so heavy models provably need 3 boards, and the
//! naive equal split (2 boards each) provably misses. The planner must
//! discover the 1/1/3/3 carve-up, and the served p99 under the planned
//! split must beat the naive equal split.

use std::time::Duration;
use superlip::bench::Harness;
use superlip::fleet::{
    equal_split, run_scenario, stats_table, worst_miss_rate, worst_p99, FleetPlan, FleetSpec,
    ModelStats, Planner, PlannerConfig, ScenarioConfig, WorkloadSpec,
};
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};

const FLEET_SIZE: usize = 8;

fn main() {
    let mut h = Harness::new("fleet_scenarios");
    let planner = Planner::new(
        FleetSpec::homogeneous(FLEET_SIZE, FpgaSpec::zcu102()),
        PlannerConfig::default(),
    );

    // Self-calibrated mix (see module doc).
    let light = |model: &str| {
        let s1 = planner.service_ms(model, 1).expect("probe");
        WorkloadSpec::new(
            model,
            0.25 / (s1 / 1e3),
            Duration::from_secs_f64(4.0 * s1 / 1e3),
        )
        .with_max_batch(2)
    };
    let heavy = |model: &str| {
        let s3 = planner.service_ms(model, 3).expect("probe");
        let s2 = planner.service_ms(model, 2).expect("probe");
        WorkloadSpec::new(
            model,
            0.2 / (s3 / 1e3),
            Duration::from_secs_f64((s3 + s2) / 2.0 / 1e3),
        )
    };
    let mix = vec![
        light("alexnet"),
        light("squeezenet"),
        heavy("vgg16"),
        heavy("yolo"),
    ];
    let mut t = Table::new(&["Model", "Rate(rps)", "Deadline(ms)", "MaxBatch"]);
    for w in &mix {
        t.row(&[
            w.model.clone(),
            format!("{:.1}", w.rate_rps),
            report::ms(w.deadline_ms()),
            w.max_batch.to_string(),
        ]);
    }
    h.table("calibrated traffic mix", &t.render());

    h.measure("fleet planning (8 boards, 4 models)", || {
        std::hint::black_box(planner.plan(&mix).expect("plan"));
    });
    let planned = planner.plan(&mix).expect("plan");
    let naive = planner
        .plan_allocation(&mix, &equal_split(FLEET_SIZE, mix.len()))
        .expect("naive plan");
    h.table("planned split", &planned.summary());
    h.table("naive equal split", &naive.summary());

    let scen = ScenarioConfig {
        requests_per_model: if h.is_quick() { 20 } else { 80 },
        seed: 2026,
        // Halve wall-clock; latency ratios and miss rates are invariant.
        time_scale: 0.5,
        ..Default::default()
    };
    let serve = |label: &str, plan: &FleetPlan, h: &mut Harness| -> Vec<ModelStats> {
        let stats = run_scenario(plan, &scen).expect("scenario");
        h.table(&format!("{label} — served traffic"), &stats_table(&stats));
        stats
    };
    let ps = serve("planned split", &planned, &mut h);
    let ns = serve("naive equal split", &naive, &mut h);

    let (wp, wn) = (worst_p99(&ps), worst_p99(&ns));
    h.record("worst-case p99, planned split", wp, "ms");
    h.record("worst-case p99, naive equal split", wn, "ms");
    h.record("worst-case miss rate, planned", worst_miss_rate(&ps) * 100.0, "%");
    h.record("worst-case miss rate, naive", worst_miss_rate(&ns) * 100.0, "%");
    println!(
        "  planned split beats naive equal split on p99: {}",
        if wp < wn { "YES" } else { "NO" }
    );
    h.finish();
}
