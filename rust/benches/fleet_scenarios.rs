//! Fleet serving scenarios: mixed-model traffic on a shared multi-FPGA
//! cluster (EXPERIMENTS.md §Fleet, §Replicas).
//!
//! **Scenario 1 (mixed skew):** an 8-board ZCU102 fleet serves a 4-model
//! mix (AlexNet + SqueezeNet light/interactive, VGG16 + YOLO
//! heavy/deadline-tight). The mix is **self-calibrated** from the
//! simulator so the comparison is robust on any machine: light models get
//! a deadline of 4× their 1-board service time, heavy models a deadline
//! strictly between their 3-board and 2-board service times — so heavy
//! models provably need 3 boards, and the naive equal split (2 boards
//! each) provably misses. The planner must discover the 1/1/3/3 carve-up,
//! and the served p99 under the planned split must beat the naive equal
//! split.
//!
//! **Scenario 2 (hot-model replicas):** the same fleet serves one HOT
//! model (AlexNet at 95% of its 6-board lock-step service rate, deadline
//! 6× its 2-board service time) next to a cold SqueezeNet. Past the
//! communication knee the 6-board torus serves only ~1.8× faster than the
//! 2-board one, so the planner must autonomously elect R ≥ 2 independent
//! 2-board replicas (per-replica utilization ≈ 0.56) over the one
//! lock-step cluster (utilization 0.95, divergent wait) — and the served
//! hot-model p99 AND miss rate under the replicated plan must beat the
//! best single-cluster plan (`replicas = 1` pinned on every entry).

use std::time::Duration;
use superlip::bench::Harness;
use superlip::fleet::{
    equal_split, run_scenario, stats_table, worst_miss_rate, worst_p99, FleetPlan, FleetSpec,
    ModelStats, Planner, PlannerConfig, ScenarioConfig, WorkloadSpec,
};
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};

const FLEET_SIZE: usize = 8;

fn main() {
    let mut h = Harness::new("fleet_scenarios");
    let planner = Planner::new(
        FleetSpec::homogeneous(FLEET_SIZE, FpgaSpec::zcu102()),
        PlannerConfig::default(),
    );

    // Self-calibrated mix (see module doc).
    let light = |model: &str| {
        let s1 = planner.service_ms(model, 1).expect("probe");
        WorkloadSpec::new(
            model,
            0.25 / (s1 / 1e3),
            Duration::from_secs_f64(4.0 * s1 / 1e3),
        )
        .with_max_batch(2)
    };
    let heavy = |model: &str| {
        let s3 = planner.service_ms(model, 3).expect("probe");
        let s2 = planner.service_ms(model, 2).expect("probe");
        WorkloadSpec::new(
            model,
            0.2 / (s3 / 1e3),
            Duration::from_secs_f64((s3 + s2) / 2.0 / 1e3),
        )
    };
    let mix = vec![
        light("alexnet"),
        light("squeezenet"),
        heavy("vgg16"),
        heavy("yolo"),
    ];
    let mut t = Table::new(&["Model", "Rate(rps)", "Deadline(ms)", "MaxBatch"]);
    for w in &mix {
        t.row(&[
            w.model.clone(),
            format!("{:.1}", w.rate_rps),
            report::ms(w.deadline_ms()),
            w.max_batch.to_string(),
        ]);
    }
    h.table("calibrated traffic mix", &t.render());

    h.measure("fleet planning (8 boards, 4 models)", || {
        std::hint::black_box(planner.plan(&mix).expect("plan"));
    });
    let planned = planner.plan(&mix).expect("plan");
    let naive = planner
        .plan_allocation(&mix, &equal_split(FLEET_SIZE, mix.len()))
        .expect("naive plan");
    h.table("planned split", &planned.summary());
    h.table("naive equal split", &naive.summary());

    let scen = ScenarioConfig {
        requests_per_model: if h.is_quick() { 20 } else { 80 },
        seed: 2026,
        // Halve wall-clock; latency ratios and miss rates are invariant.
        time_scale: 0.5,
        ..Default::default()
    };
    let serve = |label: &str, plan: &FleetPlan, h: &mut Harness| -> Vec<ModelStats> {
        let stats = run_scenario(plan, &scen).expect("scenario");
        h.table(&format!("{label} — served traffic"), &stats_table(&stats));
        stats
    };
    let ps = serve("planned split", &planned, &mut h);
    let ns = serve("naive equal split", &naive, &mut h);

    let (wp, wn) = (worst_p99(&ps), worst_p99(&ns));
    h.record("worst-case p99, planned split", wp, "ms");
    h.record("worst-case p99, naive equal split", wn, "ms");
    h.record("worst-case miss rate, planned", worst_miss_rate(&ps) * 100.0, "%");
    h.record("worst-case miss rate, naive", worst_miss_rate(&ns) * 100.0, "%");
    println!(
        "  planned split beats naive equal split on p99: {}",
        if wp < wn { "YES" } else { "NO" }
    );

    hot_model_replicas(&planner, &mut h);
    h.finish();
}

/// Scenario 2: replicated sub-clusters for one hot model (module doc;
/// EXPERIMENTS.md §Replicas).
fn hot_model_replicas(planner: &Planner, h: &mut Harness) {
    let probe = |model: &str, n: usize| planner.service_ms(model, n).expect("probe") / 1e3;
    let (a2, a6) = (probe("alexnet", 2), probe("alexnet", 6));
    let sq2 = probe("squeezenet", 2);
    // Hot: 95% of the 6-board lock-step service rate; the deadline (6× the
    // 2-board service time) comfortably admits a 2-board replica but the
    // M/D/1 sojourn tail at ρ = 0.95 provably overshoots it. Cold:
    // squeezenet idling at 45% of its 2-board rate.
    let mix = vec![
        WorkloadSpec::new("alexnet", 0.95 / a6, Duration::from_secs_f64(6.0 * a2)),
        WorkloadSpec::new("squeezenet", 0.45 / sq2, Duration::from_secs_f64(6.0 * sq2)),
    ];
    println!(
        "\n  hot-model calibration: alexnet s2 {} s6 {} (knee ratio s2/s6 = {:.2}), rate {:.0} rps",
        report::ms(a2 * 1e3),
        report::ms(a6 * 1e3),
        a2 / a6,
        0.95 / a6
    );
    // The whole contrast is structural — it only exists because 6-board
    // lock-step scaling has passed the communication knee (s6 > s2/2, so
    // three 2-board replicas offer more service capacity than one 6-board
    // torus).
    assert!(a6 > a2 / 2.0, "calibration: knee must precede 6 boards");

    let replicated = planner.plan(&mix).expect("replicated plan");
    let single_mix: Vec<WorkloadSpec> =
        mix.iter().map(|w| w.clone().with_replicas(1)).collect();
    let single = planner.plan(&single_mix).expect("single-cluster plan");
    h.table("hot-model mix — replicated plan", &replicated.summary());
    h.table("hot-model mix — best single-cluster plan", &single.summary());

    // Acceptance: the planner autonomously elects R ≥ 2 replicas for
    // exactly one model (the hot one), and the analytic contrast is
    // structural: replicated risk meets the deadline, single-cluster
    // provably misses it.
    let hot_reps = replicated.replicas_of("alexnet");
    assert!(hot_reps >= 2, "hot model must replicate:\n{}", replicated.summary());
    assert_eq!(
        replicated.replicas_of("squeezenet"),
        1,
        "exactly one model replicates:\n{}",
        replicated.summary()
    );
    assert!(replicated.worst_risk < 1.0, "{}", replicated.summary());
    assert!(single.worst_risk > 1.0, "{}", single.summary());
    h.record("hot-model replicas chosen", hot_reps as f64, "");

    // Duration-based arrivals: hot and cold streams cover the SAME model
    // timeline (~680 hot + ~56 cold requests over 1 s), so the
    // single-cluster queue transient at ρ = 0.95 has time to build — a
    // fixed per-model count would truncate it (the event-sim calibration
    // puts the hot-model contrast at ≥ 12 ms p99 / ≥ 5 pp miss across
    // seeds even at the quick 0.6 s horizon).
    let scen = ScenarioConfig {
        duration_s: Some(if h.is_quick() { 0.6 } else { 1.0 }),
        seed: 4242,
        time_scale: 0.5,
        ..Default::default()
    };
    let rs = run_scenario(&replicated, &scen).expect("replicated scenario");
    let ss = run_scenario(&single, &scen).expect("single-cluster scenario");
    h.table("replicated plan — served traffic", &stats_table(&rs));
    h.table("best single-cluster plan — served traffic", &stats_table(&ss));

    let hot_row = |rows: &[ModelStats]| -> ModelStats {
        rows.iter().find(|r| r.model == "alexnet").expect("hot row").clone()
    };
    let (hr, hs) = (hot_row(&rs), hot_row(&ss));
    h.record("hot-model p99, replicated", hr.p99_ms, "ms");
    h.record("hot-model p99, single-cluster", hs.p99_ms, "ms");
    h.record("hot-model miss rate, replicated", hr.miss_rate * 100.0, "%");
    h.record("hot-model miss rate, single-cluster", hs.miss_rate * 100.0, "%");
    println!(
        "  replicated beats single-cluster on the hot model: p99 {}  miss {}",
        if hr.p99_ms < hs.p99_ms { "YES" } else { "NO" },
        if hr.miss_rate < hs.miss_rate { "YES" } else { "NO" },
    );
    assert!(
        hr.p99_ms < hs.p99_ms,
        "replicated hot p99 {:.2} ms must beat single-cluster {:.2} ms",
        hr.p99_ms,
        hs.p99_ms
    );
    assert!(
        hr.miss_rate < hs.miss_rate,
        "replicated hot miss {:.1}% must beat single-cluster {:.1}%",
        hr.miss_rate * 100.0,
        hs.miss_rate * 100.0
    );
}
