//! Figure 14: latency-prediction accuracy — the paper's model stays within
//! a few percent of on-board (simulated) execution across designs, while
//! the [14] roofline model diverges (18.49% at ⟨10,22⟩, 45.47% at ⟨8,32⟩)
//! exactly when designs become communication-bound; on the compute-bound
//! ⟨12,16⟩ both agree. [14] has no 2-FPGA story at all.

use superlip::analytic::{self, baseline, network_latency, Design, XferMode};
use superlip::bench::Harness;
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let mut h = Harness::new("fig14_model_accuracy");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = {
        // Figure 14 evaluates the Figure 2 subject: AlexNet conv5 as a
        // standalone layer (its designs ⟨12,16⟩/⟨10,22⟩/⟨8,32⟩ tile conv5's
        // per-group channels; conv5 is where ⟨8,32⟩ turns IFM-bound).
        let alex = zoo::alexnet();
        superlip::model::Network::new("alexnet-conv5", vec![alex.layers[4].clone()])
    };
    let bus_words = fpga.mem_bus_bits / 32;

    let mut t = Table::new(&[
        "Design", "FPGAs", "[14] kcyc", "Ours kcyc", "On-board kcyc", "[14] dev", "Our dev",
    ]);
    let mut our_devs = Vec::new();
    let mut their_devs = Vec::new();
    for (tm, tn) in [(12u64, 16u64), (10, 22), (8, 32)] {
        let d = Design::float32(tm, tn, 13, 13);
        let ours = network_latency(&net, &d);
        let theirs: u64 = net
            .conv_layers()
            .map(|l| baseline::fpga15_latency(l, &d, bus_words).cycles)
            .sum();
        let sim = simulate_network(&net, &d, &Factors::single(), &fpga, &cfg, XferMode::Xfer)
            .cycles;
        let dev_ours = (sim as f64 - ours as f64).abs() / sim as f64;
        let dev_theirs = (sim as f64 - theirs as f64).abs() / sim as f64;
        our_devs.push(dev_ours);
        their_devs.push(dev_theirs);
        t.row(&[
            format!("<{tm},{tn}>"),
            "1".into(),
            (theirs / 1000).to_string(),
            (ours / 1000).to_string(),
            (sim / 1000).to_string(),
            report::pct(dev_theirs),
            report::pct(dev_ours),
        ]);
    }
    // 2-FPGA design (ours only).
    let d = Design::float32(8, 32, 13, 13);
    let f = Factors::new(1, 1, 1, 2);
    let ours2 = analytic::xfer_network_latency(&net, &d, &f, &fpga, XferMode::Xfer);
    let sim2 = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer).cycles;
    let dev2 = (sim2 as f64 - ours2 as f64).abs() / sim2 as f64;
    our_devs.push(dev2);
    t.row(&[
        "<8,32> 2FPGA".into(),
        "2".into(),
        "n/a".into(),
        (ours2 / 1000).to_string(),
        (sim2 / 1000).to_string(),
        "n/a".into(),
        report::pct(dev2),
    ]);
    h.table("Figure 14: predicted vs on-board latency", &t.render());

    let avg_ours = our_devs.iter().sum::<f64>() / our_devs.len() as f64;
    h.record("our model avg deviation", avg_ours * 100.0, "% (paper: 2.53%)");
    h.record(
        "[14] deviation at <8,32>",
        their_devs[2] * 100.0,
        "% (paper: 45.47%)",
    );
    h.record(
        "[14] deviation at <12,16>",
        their_devs[0] * 100.0,
        "% (paper: ~0% — compute-bound)",
    );
    println!(
        "  shape: ours accurate everywhere, [14] diverges when comm-bound: {}",
        if avg_ours < 0.06 && their_devs[2] > 0.15 && their_devs[0] < 0.05 {
            "REPRODUCED"
        } else {
            "NOT reproduced"
        }
    );

    h.measure("full accuracy sweep", || {
        for (tm, tn) in [(12u64, 16u64), (10, 22), (8, 32)] {
            let d = Design::float32(tm, tn, 13, 13);
            std::hint::black_box(simulate_network(
                &net,
                &d,
                &Factors::single(),
                &fpga,
                &cfg,
                XferMode::Xfer,
            ));
        }
    });
    h.finish();
}
