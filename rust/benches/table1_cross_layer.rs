//! Table 1: layer-specific optima vs the cross-layer uniform design for
//! AlexNet on 4 FPGAs — the uniform design should land within ~5% of the
//! per-layer total (which would additionally pay reconfiguration), and the
//! exploration itself should be fast ("Elap." column).

use superlip::analytic::{xfer_layer_latency, XferMode};
use superlip::bench::Harness;
use superlip::dse::{self, best_layer_design};
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::{FpgaSpec, Precision};
use superlip::report::Table;
use std::time::Instant;

fn main() {
    let mut h = Harness::new("table1_cross_layer");
    let fpga = FpgaSpec::zcu102();
    let net = zoo::alexnet();
    let p = Precision::Fixed16;
    let n_fpgas = 4u64;

    // --- Layer-specific optimization: per layer, best design + best
    // partition over 4 FPGAs.
    let mut t = Table::new(&[
        "AlexNet", "Tm", "Tn", "Tr", "Tc", "Partition", "kcycles", "Elap(s)",
    ]);
    let mut custom_total = 0u64;
    for l in net.conv_layers() {
        let t0 = Instant::now();
        let (d, _ll, _stats) = best_layer_design(l, &fpga, p);
        // Best factors for this single layer.
        let single_net = superlip::model::Network::new(&l.name, vec![l.clone()]);
        let (f, cycles) = dse::best_factors(&single_net, &d, &fpga, n_fpgas, XferMode::Xfer);
        custom_total += cycles;
        t.row(&[
            l.name.clone(),
            d.tm.to_string(),
            d.tn.to_string(),
            d.tr.to_string(),
            d.tc.to_string(),
            f.to_string(),
            (cycles / 1000).to_string(),
            format!("{:.2}", t0.elapsed().as_secs_f64()),
        ]);
    }

    // --- Cross-layer uniform design.
    let t0 = Instant::now();
    let uni = dse::best_uniform_design(&net, &fpga, p);
    let (uf, uni_cycles) = dse::best_factors(&net, &uni.design, &fpga, n_fpgas, XferMode::Xfer);
    let uni_elapsed = t0.elapsed().as_secs_f64();
    t.row(&[
        "Cross-Layer".into(),
        uni.design.tm.to_string(),
        uni.design.tn.to_string(),
        uni.design.tr.to_string(),
        uni.design.tc.to_string(),
        uf.to_string(),
        (uni_cycles / 1000).to_string(),
        format!("{uni_elapsed:.2}"),
    ]);
    h.table("Table 1: layer-specific vs cross-layer (4 FPGAs, fx16)", &t.render());

    let overhead = uni_cycles as f64 / custom_total as f64 - 1.0;
    h.record("layer-specific total", (custom_total / 1000) as f64, "kcycles");
    h.record("cross-layer uniform", (uni_cycles / 1000) as f64, "kcycles");
    h.record(
        "uniform overhead vs custom",
        overhead * 100.0,
        "% (paper: ~4%; customized also pays reconfig)",
    );

    // Exploration cost is the Table's "Elap." story: everything in seconds.
    h.measure("cross-layer DSE (full)", || {
        std::hint::black_box(dse::best_uniform_design(&net, &fpga, p));
    });

    // Show the uniform plan remains eq-22-feasible per layer.
    let all_ok = net
        .conv_layers()
        .all(|l| xfer_layer_latency(l, &uni.design, &uf, &fpga, XferMode::Xfer).bandwidth_ok);
    h.record("eq22 feasible on all layers", f64::from(u8::from(all_ok)), "(1=yes)");
    let _ = Factors::single();
    h.finish();
}
