//! Multi-tenant graceful overload (EXPERIMENTS.md §Overload): a gold-class
//! model and a best-effort model share a planned fleet; mid-run the
//! best-effort stream flash-floods to several times its declared rate. The
//! brownout ladder must climb one rung at a time — tighten the victim's
//! queue caps (explicit typed sheds), swap its lanes one precision rung
//! down (fx16 → fx8), raise the ingress admission floor — while **gold
//! p99 and miss rate stay flat**, and then walk fully back down once the
//! flood ends. Every shed request gets an explicit rejection: per class,
//! `completed + shed == sent` (exactly-one-response, even under overload).
//!
//! Self-calibrated three-phase scenario on a 4-board fleet:
//!
//! * **pre-overload** — alexnet (gold) at half its 3-board service rate,
//!   squeezenet (best-effort) at 30% of its 1-board rate. The planner
//!   scores gold at `rate × 1.5` (`--surge-factor` semantics), reserving
//!   flash-crowd headroom;
//! * **overload** — the best-effort rate multiplies past the surge ratio
//!   AND past its lane capacity (ρ > 1.5), so the queue genuinely
//!   explodes; the ladder climbs shed → degrade → admission;
//! * **recovery** — rates return to the declared mix; calm windows walk
//!   the ladder back to normal (floor lowered, full precision restored,
//!   caps released).

use std::time::Duration;
use superlip::bench::Harness;
use superlip::control::{run_drift_scenario, BrownoutConfig, ControlConfig, OnlineConfig};
use superlip::fleet::{
    stats_table, FleetSpec, PhaseSpec, Planner, PlannerConfig, SloClass, WorkloadSpec,
};
use superlip::platform::FpgaSpec;
use superlip::report;

const FLEET_SIZE: usize = 4;

fn main() {
    let mut h = Harness::new("overload_brownout");
    let fleet = FleetSpec::homogeneous(FLEET_SIZE, FpgaSpec::zcu102());
    let pcfg = PlannerConfig {
        surge_factor: 1.5,
        ..PlannerConfig::default()
    };
    let planner = Planner::new(fleet.clone(), pcfg);
    let probe = |model: &str, n: usize| planner.service_ms(model, n).expect("probe") / 1e3;
    let (a1, a3) = (probe("alexnet", 1), probe("alexnet", 3));
    let q1 = probe("squeezenet", 1);

    let gold_rate = 0.5 / a3;
    let be_rate = 0.3 / q1;
    // Flood multiple: ≥ 5× the declared rate (well past the 1.5 surge
    // ratio, and ρ ≈ 1.5 against the victim's one-board capacity), raised
    // if needed so every overload window offers ≥ 20 victim requests —
    // comfortably over the ladder's min_offered sample gate.
    let tick_s = 0.1;
    let flood_mult = (20.0 / (be_rate * tick_s)).max(5.0);
    let flood = be_rate * flood_mult;
    let mix = vec![
        WorkloadSpec::new("alexnet", gold_rate, Duration::from_secs_f64(6.0 * a1))
            .with_class(SloClass::Gold),
        WorkloadSpec::new("squeezenet", be_rate, Duration::from_secs_f64(6.0 * q1))
            .with_class(SloClass::BestEffort)
            .with_max_batch(4),
    ];
    println!(
        "  calibration: alexnet s1 {} s3 {} (gold {gold_rate:.0} rps), squeezenet s1 {} \
         (best-effort {be_rate:.0} rps, flood ×{flood_mult:.1} = {flood:.0} rps)",
        report::ms(a1 * 1e3),
        report::ms(a3 * 1e3),
        report::ms(q1 * 1e3)
    );

    let (base_s, flood_s, recover_s) = if h.is_quick() {
        (0.5, 0.7, 1.0)
    } else {
        (0.8, 1.0, 1.4)
    };
    let phases = vec![
        PhaseSpec {
            duration_s: base_s,
            rates_rps: vec![gold_rate, be_rate],
        },
        PhaseSpec {
            duration_s: flood_s,
            rates_rps: vec![gold_rate, flood],
        },
        PhaseSpec {
            duration_s: recover_s,
            rates_rps: vec![gold_rate, be_rate],
        },
    ];
    // Fast ladder for a short bench: one pressured window climbs, two calm
    // windows descend (the flap-proofing property tests live in
    // `control::brownout`; here we exercise the full climb + recovery).
    // With enter_hysteresis 1, a single noisy calm window would climb the
    // ladder spuriously, so the surge ratio is pinned well above Poisson
    // window noise (the ×5+ flood clears it every window regardless) —
    // Monte-Carlo'd flake-free across 4000 seeded runs per mode.
    let cfg = OnlineConfig {
        seed: 2026,
        time_scale: 0.5,
        tick_s,
        recv_timeout: Duration::from_secs(60),
        control: ControlConfig {
            brownout: Some(BrownoutConfig {
                enter_hysteresis: 1,
                exit_hysteresis: 2,
                min_offered: 10,
                surge_ratio: 2.5,
                ..BrownoutConfig::default()
            }),
            ..ControlConfig::default()
        },
        ..OnlineConfig::default()
    };
    let plan = planner.plan(&mix).expect("plan");
    h.table("initial plan (surge-aware, gold scored at 1.5× rate)", &plan.summary());

    let out = run_drift_scenario(&fleet, pcfg, &mix, &phases, &cfg, true).expect("scenario");
    for (pi, rows) in out.phase_stats.iter().enumerate() {
        let label = ["pre-overload", "overload", "recovery"][pi];
        h.table(&format!("phase {pi} ({label}) — served traffic"), &stats_table(rows));
    }
    for e in &out.events {
        println!("    [control] {e}");
    }

    let row = |pi: usize, model: &str| {
        out.phase_stats[pi]
            .iter()
            .find(|r| r.model == model)
            .expect("stats row")
            .clone()
    };
    let (g_base, g_flood) = (row(0, "alexnet"), row(1, "alexnet"));
    let b_flood = row(1, "squeezenet");
    let be_shed_rate = b_flood.shed as f64 / b_flood.sent.max(1) as f64;

    h.record("gold p99, pre-overload", g_base.p99_ms, "ms");
    h.record("gold p99, overload", g_flood.p99_ms, "ms");
    h.record("gold miss, overload", g_flood.miss_rate * 100.0, "%");
    h.record("best-effort p99, overload", b_flood.p99_ms, "ms");
    h.record("best-effort shed rate, overload", be_shed_rate * 100.0, "%");
    h.record("final brownout rung", out.final_rung as f64, "");
    println!(
        "  gold holds: p99 {} → {}  miss {:.1}% → {:.1}%; best-effort shed {:.0}% of the flood",
        report::ms(g_base.p99_ms),
        report::ms(g_flood.p99_ms),
        g_base.miss_rate * 100.0,
        g_flood.miss_rate * 100.0,
        be_shed_rate * 100.0
    );

    // Acceptance (ISSUE 6): gold p99 + miss stay flat through the flood —
    // the surge lands entirely on the victim class.
    assert!(
        g_flood.p99_ms <= g_base.p99_ms * 1.5 + 2.0,
        "gold p99 must hold through the overload: {} pre vs {} during",
        report::ms(g_base.p99_ms),
        report::ms(g_flood.p99_ms)
    );
    assert!(
        g_flood.miss_rate <= g_base.miss_rate + 0.03,
        "gold miss must hold through the overload: {:.1}% pre vs {:.1}% during",
        g_base.miss_rate * 100.0,
        g_flood.miss_rate * 100.0
    );
    for pi in 0..3 {
        assert_eq!(
            row(pi, "alexnet").shed,
            0,
            "gold is never shed (phase {pi}): {:?}",
            out.events
        );
    }
    // The ladder walked ≥ 2 distinct rungs: queue-cap shedding AND the
    // precision degrade (the fx8 lane swap) both happened.
    assert!(
        out.events.iter().any(|e| e.contains("climbed to rung `shed`")),
        "rung 1 (shed) must engage: {:?}",
        out.events
    );
    assert!(
        out.events.iter().any(|e| e.contains("climbed to rung `degrade`")),
        "rung 2 (degrade) must engage: {:?}",
        out.events
    );
    assert!(
        out.events.iter().any(|e| e.contains("swapped to 8bits fixed")),
        "the degrade rung must swap the victim lane to fx8: {:?}",
        out.events
    );
    assert!(
        b_flood.shed > 0,
        "the flood must shed best-effort traffic: {b_flood:?}"
    );
    // Every shed was an explicit typed rejection, every accepted request
    // got exactly one response — nothing was silently dropped, per class.
    for (pi, rows) in out.phase_stats.iter().enumerate() {
        for r in rows {
            assert_eq!(
                r.completed + r.shed,
                r.sent,
                "phase {pi} {}: exactly one outcome per request (completed {} + shed {} vs sent {})",
                r.model,
                r.completed,
                r.shed,
                r.sent
            );
        }
    }
    // No concurrent drift migration fought the ladder: overload is the
    // ladder's to handle (re-plans suppressed while engaged).
    assert_eq!(
        out.replans, 0,
        "the ladder owns the overload — no drift re-plan may fire: {:?}",
        out.events
    );
    // Full recovery: the ladder descended every rung it climbed.
    assert_eq!(
        out.final_rung, 0,
        "the ladder must fully recover after the flood: {:?}",
        out.events
    );
    assert!(
        out.events
            .iter()
            .any(|e| e.contains("descended to rung `normal`")),
        "recovery must be logged rung by rung: {:?}",
        out.events
    );
    h.finish();
}
