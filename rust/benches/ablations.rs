//! Ablation studies for the design choices DESIGN.md calls out:
//!   1. adaptive offload (XFER falls back to replication) vs forced modes;
//!   2. interleaved (Fig 11b) vs blocked (Fig 11a) inter-layer placement;
//!   3. simulator sync-overhead sensitivity (model-accuracy driver);
//!   4. stream-preset pruning (maximal-only) vs the full ladder;
//!   5. heterogeneous cluster (§7 future work) vs its members.

use superlip::analytic::{layer_latency, Design, XferMode};
use superlip::bench::Harness;
use superlip::dse;
use superlip::model::zoo;
use superlip::partition::hetero::{hetero_row_partition, HeteroNode};
use superlip::partition::{interlayer_traffic_elems, Factors, PlacementPolicy};
use superlip::platform::{FpgaSpec, Precision};
use superlip::report::Table;
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let mut h = Harness::new("ablations");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = zoo::alexnet();

    // --- 1. Adaptive offload: XFER with fallback vs pure baseline.
    // (A forced-offload mode is what the raw eqs 16–21 would do; adaptive
    // equals it when offload helps and beats it when it would not.)
    let d = Design::fixed16(128, 10, 7, 14);
    let mut t = Table::new(&["Factors", "Baseline kcyc", "XFER(adaptive) kcyc", "Gain"]);
    for f in [Factors::new(1, 2, 1, 1), Factors::new(1, 2, 1, 2), Factors::new(1, 4, 1, 1)] {
        let base = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Baseline).cycles;
        let xfer = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer).cycles;
        t.row(&[
            f.to_string(),
            (base / 1000).to_string(),
            (xfer / 1000).to_string(),
            format!("{:.2}%", (1.0 - xfer as f64 / base as f64) * 100.0),
        ]);
    }
    h.table("Ablation 1: traffic offload (adaptive XFER) vs replication", &t.render());

    // --- 2. Placement policy: inter-layer traffic volumes.
    let f = Factors::new(1, 1, 1, 2);
    let conv: Vec<_> = net.conv_layers().collect();
    let mut blocked = 0u64;
    let mut interleaved = 0u64;
    for w in conv.windows(2) {
        blocked += interlayer_traffic_elems(w[0], w[1], &f, PlacementPolicy::Blocked);
        interleaved += interlayer_traffic_elems(w[0], w[1], &f, PlacementPolicy::Interleaved);
    }
    h.record("blocked placement traffic (Fig 11a)", blocked as f64, "elems");
    h.record("interleaved placement traffic (Fig 11b)", interleaved as f64, "elems (paper: 0)");

    // --- 3. Sync-overhead sensitivity: how far can the handshake grow
    // before the model's ~2.5% accuracy story breaks?
    let dval = Design::float32(10, 22, 13, 13);
    let model = superlip::analytic::network_latency(&net, &dval);
    let mut t = Table::new(&["sync_cycles", "sim kcyc", "model deviation"]);
    for sync in [0u64, 6, 12, 24, 48, 96] {
        let mut c = cfg;
        c.sync_cycles = sync;
        let sim = simulate_network(&net, &dval, &Factors::single(), &fpga, &c, XferMode::Xfer)
            .cycles;
        t.row(&[
            sync.to_string(),
            (sim / 1000).to_string(),
            format!("{:.2}%", (sim as f64 - model as f64).abs() / sim as f64 * 100.0),
        ]);
    }
    h.table("Ablation 3: double-buffer handshake cost vs model accuracy", &t.render());

    // --- 4. Stream-preset pruning: maximal-only presets must not lose
    // quality vs a dense ladder (they provably cannot — latency is
    // monotone in each width), while shrinking the search.
    let presets = dse::stream_presets(Precision::Fixed16, &fpga);
    h.record("maximal stream presets (fx16)", presets.len() as f64, "combos (full ladder: 125)");
    let (best_d, best_ll, stats) =
        dse::best_layer_design(&net.layers[2], &fpga, Precision::Fixed16);
    h.record("conv3 optimum with pruned presets", best_ll.lat as f64, "cycles");
    h.record("conv3 designs evaluated", stats.evaluated as f64, "");
    let _ = best_d;

    // --- 5. Heterogeneous cluster (§7): big + half-size board.
    let big = HeteroNode {
        fpga: FpgaSpec::zcu102(),
        design: Design::fixed16(128, 10, 7, 14),
    };
    let small = HeteroNode {
        fpga: {
            let mut f = FpgaSpec::zcu102();
            f.dsp /= 2;
            f.bram18k /= 2;
            f
        },
        design: Design::fixed16(64, 10, 7, 14),
    };
    let l = net.layers[2].clone();
    let solo_ms = big
        .design
        .precision
        .cycles_to_ms(layer_latency(&l, &big.design).lat);
    let (rows, hetero_ms) = hetero_row_partition(&l, &[big, small]);
    h.record("conv3 solo big-board", solo_ms, "ms");
    h.record("conv3 hetero big+half", hetero_ms, "ms");
    h.record("hetero row split", rows[0] as f64, &format!("rows of {} (small gets {})", l.r, rows[1]));

    h.measure("hetero partition of all conv layers", || {
        let big = HeteroNode {
            fpga: FpgaSpec::zcu102(),
            design: Design::fixed16(128, 10, 7, 14),
        };
        let small = HeteroNode {
            fpga: FpgaSpec::zcu102(),
            design: Design::fixed16(64, 10, 7, 14),
        };
        for l in net.conv_layers() {
            std::hint::black_box(hetero_row_partition(l, &[big.clone(), small.clone()]));
        }
    });
    h.finish();
}
