//! Figure 3: XFER on a weight-shared 2-FPGA partition reduces the pipeline
//! cycle time Lat2 (paper: 2953 → 1782 cycles, 39.65%).

use superlip::analytic::{xfer_layer_latency, Design, XferMode};
use superlip::bench::Harness;
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::FpgaSpec;
use superlip::report::Table;

fn main() {
    let mut h = Harness::new("fig3_xfer_gain");
    let fpga = FpgaSpec::zcu102();
    let net = zoo::alexnet();
    let f = Factors::new(1, 2, 1, 1); // weight-shared row partition

    let mut t = Table::new(&["Layer", "Base Lat2", "XFER Lat2", "Gain"]);
    let mut best_gain = 0.0f64;
    for l in net.conv_layers() {
        // A deliberately weight-bound design family (narrow Wp), as in the
        // Figure 3 example.
        let d = Design::fixed16(128, 10, 7, 14).with_streams(4, 2, 4);
        let base = xfer_layer_latency(l, &d, &f, &fpga, XferMode::Baseline);
        let xfer = xfer_layer_latency(l, &d, &f, &fpga, XferMode::Xfer);
        let gain = 1.0 - xfer.worst.lat2 as f64 / base.worst.lat2 as f64;
        best_gain = best_gain.max(gain);
        t.row(&[
            l.name.clone(),
            base.worst.lat2.to_string(),
            xfer.worst.lat2.to_string(),
            format!("{:.2}%", gain * 100.0),
        ]);
    }
    h.table(
        "Figure 3: Lat2 (pipeline cycle time) baseline vs XFER, Pr=2",
        &t.render(),
    );
    h.record("best per-layer Lat2 gain", best_gain * 100.0, "% (paper: 39.65%)");

    let d = Design::fixed16(128, 10, 7, 14).with_streams(4, 2, 4);
    h.measure("xfer_layer_latency (5 layers, 2 modes)", || {
        for l in net.conv_layers() {
            std::hint::black_box(xfer_layer_latency(l, &d, &f, &fpga, XferMode::Baseline));
            std::hint::black_box(xfer_layer_latency(l, &d, &f, &fpga, XferMode::Xfer));
        }
    });
    h.finish();
}
