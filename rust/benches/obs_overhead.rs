//! §Observability: flight-recorder overhead on the serving hot path
//! (EXPERIMENTS.md §Observability). Drives the same closed-loop
//! submit→route→batch→complete pipeline as `serving_hotpath` against a
//! null backend, twice per round — recorder DETACHED, then recorder
//! ATTACHED at 1/1024 id-sampling — and hard-asserts the tracing tax.
//!
//! Design notes:
//!
//! * Rounds are INTERLEAVED (untraced, traced, untraced, traced, ...) and
//!   each mode takes its minimum across rounds, so a frequency ramp or a
//!   noisy CI neighbor hits both modes alike instead of biasing one.
//! * The recorder is attached post-hoc via `Server::set_recorder` — the
//!   exact mechanism production uses — so the detached rounds also pay
//!   the one atomic snapshot load per batch, which is the honest
//!   "recorder compiled in but off" baseline.
//! * The acceptance gate is the ISSUE contract: at 1/1024 sampling the
//!   traced hot path must cost ≤ 5% more ns/request than the detached
//!   one. The assert uses min-of-rounds for both sides.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};
use superlip::bench::Harness;
use superlip::fleet::SloClass;
use superlip::obs::TraceRecorder;
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, RoutePolicy, Server, ServerConfig,
};

struct NullBackend;

impl InferBackend for NullBackend {
    fn image_elems(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn infer(&self, _images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        Ok(vec![0.0; n])
    }
}

const MODEL: &str = "null";
const LANES: usize = 2;
const WORKERS_PER_LANE: usize = 2;
const SUBMITTERS: usize = 3;
const PIPELINE: usize = 64;
const SAMPLE_EVERY: u64 = 1024;
const ROUNDS: usize = 5;

fn lane() -> LaneSpec {
    LaneSpec {
        model: MODEL.into(),
        factories: (0..WORKERS_PER_LANE)
            .map(|_| {
                Box::new(|| Ok(Box::new(NullBackend) as Box<dyn InferBackend>)) as BackendFactory
            })
            .collect(),
        batcher: BatcherConfig {
            max_batch: 32,
            window: Duration::from_millis(0),
            ..BatcherConfig::default()
        },
    }
}

/// One saturated closed-loop run; returns ns per completed request.
fn drive(server: &Server, per_submitter: usize) -> f64 {
    let completed = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..SUBMITTERS {
            let completed = &completed;
            s.spawn(move || {
                let deadline = Duration::from_secs(5);
                let class = match t % 3 {
                    0 => SloClass::Gold,
                    1 => SloClass::Silver,
                    _ => SloClass::BestEffort,
                };
                let mut inflight = std::collections::VecDeque::with_capacity(PIPELINE);
                let mut done = 0u64;
                for _ in 0..per_submitter {
                    let rx = server
                        .submit_to_class(MODEL, vec![0.0], deadline, class)
                        .expect("null lane accepts");
                    inflight.push_back(rx);
                    if inflight.len() >= PIPELINE {
                        inflight.pop_front().unwrap().recv().expect("response");
                        done += 1;
                    }
                }
                for rx in inflight {
                    rx.recv().expect("response");
                    done += 1;
                }
                completed.fetch_add(done, Ordering::Relaxed);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let n = completed.load(Ordering::Relaxed);
    assert_eq!(n as usize, SUBMITTERS * per_submitter, "exactly-one-response");
    wall * 1e9 / n as f64
}

fn main() {
    let mut h = Harness::new("obs_overhead");
    let per_submitter: usize = if h.is_quick() { 15_000 } else { 100_000 };

    let server = Server::start_plan(
        (0..LANES).map(|_| lane()).collect(),
        ServerConfig {
            policy: RoutePolicy::LeastOutstanding,
            ..ServerConfig::default()
        },
    );
    let recorder = TraceRecorder::new(SAMPLE_EVERY, 4096);

    // Warmup both modes (compiles the pipeline, pages the recorder rings).
    drive(&server, per_submitter / 10);
    server.set_recorder(Some(recorder.clone()));
    drive(&server, per_submitter / 10);
    server.set_recorder(None);

    let mut untraced = f64::INFINITY;
    let mut traced = f64::INFINITY;
    for _ in 0..ROUNDS {
        server.set_recorder(None);
        untraced = untraced.min(drive(&server, per_submitter));
        server.set_recorder(Some(recorder.clone()));
        traced = traced.min(drive(&server, per_submitter));
        // Drain so the ring never saturates into pure overwrite mode —
        // steady-state production drains periodically too.
        let _ = recorder.take();
    }
    server.set_recorder(None);

    let overhead_pct = (traced / untraced - 1.0) * 100.0;
    h.record("hot path untraced", untraced, "ns/req");
    h.record("hot path traced (1/1024)", traced, "ns/req");
    h.record("recorder overhead", overhead_pct, "pct-info");
    h.record("traces published", recorder.published() as f64, "records");

    // The ISSUE contract: 1/1024 sampling costs ≤ 5% on the hot path.
    assert!(
        traced <= untraced * 1.05,
        "recorder overhead {overhead_pct:.2}% exceeds the 5% budget \
         (untraced {untraced:.1} ns/req, traced {traced:.1} ns/req)"
    );

    server.shutdown();
    h.finish();
}
