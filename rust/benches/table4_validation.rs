//! Table 4: model validation + bottleneck detection/alleviation. For the
//! four designs A–D: our model's cycles/BRAM/DSP vs the "on-board"
//! (simulated) values, the Corollary-1 bound, and the XFER speedups
//! (paper: 3.30× and 3.43×).

use superlip::analytic::{
    self, check_feasible, detect, network_latency, xfer_network_latency, Design, XferMode,
};
use superlip::bench::Harness;
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let mut h = Harness::new("table4_validation");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = zoo::alexnet();

    // Designs A–D of Table 4 (IFM-bound f32; weight-bound fx16; each with
    // its XFER partner partition).
    let a = Design::float32(8, 32, 13, 13);
    let c = Design::fixed16(64, 20, 13, 13).with_streams(8, 2, 8);
    let rows: [(&str, Design, Factors); 4] = [
        ("A (single)", a, Factors::single()),
        ("B (XFER Pm=2)", a, Factors::new(1, 1, 1, 2)),
        ("C (single)", c, Factors::single()),
        ("D (XFER Pr=2)", c, Factors::new(1, 2, 1, 1)),
    ];

    let mut t = Table::new(&[
        "Design", "Bound", "Model kcyc", "Sim kcyc", "Cyc dev", "BRAM", "DSP", "Speedup",
    ]);
    let mut sim_cycles = [0u64; 4];
    for (i, (label, d, f)) in rows.iter().enumerate() {
        let model = if f.num_fpgas() == 1 {
            network_latency(&net, d)
        } else {
            xfer_network_latency(&net, d, f, &fpga, XferMode::Xfer)
        };
        let sim = simulate_network(&net, d, f, &fpga, &cfg, XferMode::Xfer).cycles;
        sim_cycles[i] = sim;
        let k_max = net.conv_layers().map(|l| l.k).max().unwrap();
        let usage = check_feasible(d, &fpga, k_max).unwrap();
        let worst = net
            .conv_layers()
            .map(|l| analytic::xfer_layer_latency(l, d, f, &fpga, XferMode::Xfer))
            .max_by_key(|x| x.worst.lat)
            .unwrap();
        let speedup = if i % 2 == 1 {
            format!("{:.2}x", sim_cycles[i - 1] as f64 / sim as f64)
        } else {
            "baseline".into()
        };
        t.row(&[
            label.to_string(),
            detect(&worst.worst).label().into(),
            (model / 1000).to_string(),
            (sim / 1000).to_string(),
            report::pct((sim as f64 - model as f64).abs() / sim as f64),
            usage.bram_total().to_string(),
            usage.dsp.to_string(),
            speedup,
        ]);
    }
    h.table("Table 4: validation + bottleneck alleviation (AlexNet)", &t.render());

    let dev_a = {
        let model = network_latency(&net, &a) as f64;
        let sim = sim_cycles[0] as f64;
        (sim - model).abs() / sim
    };
    h.record("design A cycle deviation", dev_a * 100.0, "% (paper: ~3%)");
    h.record(
        "B vs A speedup",
        sim_cycles[0] as f64 / sim_cycles[1] as f64,
        "x (paper: 3.30x)",
    );
    h.record(
        "D vs C speedup",
        sim_cycles[2] as f64 / sim_cycles[3] as f64,
        "x (paper: 3.43x)",
    );

    h.measure("validate all four designs (model+sim)", || {
        for (_, d, f) in rows.iter() {
            std::hint::black_box(simulate_network(&net, d, f, &fpga, &cfg, XferMode::Xfer));
        }
    });
    h.finish();
}
