//! Re-plan latency: incremental (`control::Replanner::plan_incremental`)
//! vs from-scratch re-planning, at three fleet scales (EXPERIMENTS.md
//! §Replan latency).
//!
//! The steady-state control loop re-plans on every drift breach, so
//! re-plan latency bounds how fast the fleet can track a moving mix. The
//! incremental path keeps the previous board allocation, reuses clean
//! models' deployments byte-for-byte, and re-scores only the models whose
//! observed rate left the tolerance band — O(dirty) cached-sub-plan
//! arithmetic instead of a composition search over the whole fleet.
//!
//! Scales and baselines:
//!
//! * **8 boards / 5 models** — the scratch baseline is the real full
//!   composition search (`Planner::plan`, C(7,4) = 35 compositions).
//! * **64 boards / 10 models** and **256 boards / 50 models** — the full
//!   search is combinatorially infeasible (C(63,9) ≈ 6·10^10), which is
//!   exactly the paper-scale motivation for incremental re-planning. The
//!   scratch baseline there is the honest non-incremental alternative: an
//!   all-dirty `Planner::plan_allocation` that re-scores every model at
//!   its observed rate under the fixed allocation.
//!
//! Each timed iteration drifts rates to *fresh* values (deterministic
//! `SplitMix64` jitter) so the split memo cannot short-circuit the work
//! being measured: scratch re-scores all M models, incremental re-scores
//! exactly one. Sub-plan caches are warmed before timing in both arms —
//! the contrast is re-plan algorithm, not cold-start DSE.
//!
//! Acceptance (generous slack for CI noise; the perf trajectory proper is
//! gated by `tools/compare_bench.py` against `BENCH_replan.json`):
//! incremental stays well under 1 ms at 8 boards and well under 100 ms at
//! 256 boards, and every incremental re-plan re-scores exactly the one
//! drifted model.

use std::time::{Duration, Instant};
use superlip::bench::Harness;
use superlip::control::Replanner;
use superlip::fleet::{FleetSpec, Planner, PlannerConfig, WorkloadSpec};
use superlip::platform::FpgaSpec;
use superlip::util::SplitMix64;

const BASES: [&str; 4] = ["alexnet", "squeezenet", "vgg16", "yolo"];

fn fleet(n: usize) -> FleetSpec {
    FleetSpec::homogeneous(n, FpgaSpec::zcu102())
}

/// `m` variant-tagged models cycling the zoo's base networks, each
/// calibrated to ~0.3 single-board occupancy with a 20× service-time
/// deadline — comfortably feasible on one board, so any allocation with
/// ≥1 board per model is stable and rate jitter cannot tip a model into
/// infeasibility (which would trigger the full-search rescue and poison
/// the timing).
fn mix_for(planner: &Planner, m: usize) -> Vec<WorkloadSpec> {
    let per_base: Vec<(f64, f64)> = BASES
        .iter()
        .map(|b| {
            let s1 = planner.service_ms(b, 1).expect("probe");
            (0.3 / (s1 / 1e3), 20.0 * s1)
        })
        .collect();
    (0..m)
        .map(|i| {
            let (rate, dl_ms) = per_base[i % BASES.len()];
            WorkloadSpec::new(
                &format!("{}#{i:02}", BASES[i % BASES.len()]),
                rate,
                Duration::from_secs_f64(dl_ms / 1e3),
            )
        })
        .collect()
}

/// Near-even split of `boards` across `m` models (remainder to the first
/// models), the fixed allocation both big-fleet arms re-plan under.
fn even_counts(boards: usize, m: usize) -> Vec<usize> {
    let (q, r) = (boards / m, boards % m);
    (0..m).map(|i| q + usize::from(i < r)).collect()
}

/// Rate multiplier in [0.85, 1.18) — wide enough that every draw is a
/// genuine split-memo miss, narrow enough to stay feasible.
fn jitter(rng: &mut SplitMix64) -> f64 {
    0.85 + rng.below(330) as f64 / 1000.0
}

struct Scale {
    boards: usize,
    models: usize,
    /// Scratch arm = true full composition search (small fleets only).
    full_search: bool,
}

fn main() {
    let mut h = Harness::new("replan_latency");
    let iters: usize = if h.is_quick() { 5 } else { 40 };
    let scales = [
        Scale { boards: 8, models: 5, full_search: true },
        Scale { boards: 64, models: 10, full_search: false },
        Scale { boards: 256, models: 50, full_search: false },
    ];

    let mut rows = String::new();
    for sc in &scales {
        let tag = format!("{} boards / {} models", sc.boards, sc.models);
        let pcfg = PlannerConfig::default();
        let scratch = Planner::new(fleet(sc.boards), pcfg);
        let base = mix_for(&scratch, sc.models);
        let counts = if sc.full_search {
            scratch.plan(&base).expect("seed plan").allocation()
        } else {
            even_counts(sc.boards, sc.models)
        };

        // Seed the replanner's plan memory (big fleets cannot seed through
        // the full-search fallback) and warm both arms' sub-plan caches.
        let mut rp = Replanner::new(fleet(sc.boards), pcfg);
        rp.adopt_cache(&scratch);
        let seed = scratch.plan_allocation(&base, &counts).expect("seed");
        assert!(seed.worst_risk.is_finite(), "{tag}: infeasible seed mix");
        rp.adopt_plan(&seed);

        // Scratch arm: every model re-scored at freshly jittered rates.
        let mut rng = SplitMix64::new(0x5eed_0000 + sc.boards as u64);
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut observed = base.clone();
            for w in observed.iter_mut() {
                w.rate_rps *= jitter(&mut rng);
            }
            let plan = if sc.full_search {
                scratch.plan(&observed).expect("scratch plan")
            } else {
                scratch.plan_allocation(&observed, &counts).expect("scratch plan")
            };
            assert!(plan.worst_risk.is_finite());
        }
        let scratch_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

        // Incremental arm: one model drifts per tick, rotating.
        let mut incr_rng = SplitMix64::new(0x1ec2_0000 + sc.boards as u64);
        let t1 = Instant::now();
        for it in 0..iters {
            let dirty = it % sc.models;
            let mut observed = base.clone();
            observed[dirty].rate_rps *= jitter(&mut incr_rng);
            let mut moved = vec![false; sc.models];
            moved[dirty] = true;
            let out = rp.plan_incremental(&observed, &moved).expect("incremental");
            assert!(out.incremental, "{tag}: fell back to full search");
            assert_eq!(out.rescored.len(), 1, "{tag}: re-scored more than the drifted model");
            assert_eq!(out.reused.len(), sc.models - 1);
        }
        let incr_us = t1.elapsed().as_secs_f64() * 1e6 / iters as f64;

        let label = if sc.full_search { "scratch full search" } else { "scratch all-dirty" };
        h.record(&format!("{tag}, {label}"), scratch_us, "us/replan");
        h.record(&format!("{tag}, incremental"), incr_us, "us/replan");
        rows.push_str(&format!(
            "{tag:<24} {scratch_us:>12.1} us ({label})  {incr_us:>10.1} us incremental  ({:.1}x)\n",
            scratch_us / incr_us.max(1e-9)
        ));

        // ISSUE targets with ~20x slack for noisy CI hosts; the tight
        // trajectory is gated against BENCH_replan.json.
        if !h.is_quick() {
            match sc.boards {
                8 => assert!(incr_us < 20_000.0, "8-board incremental re-plan: {incr_us:.1} us"),
                256 => assert!(incr_us < 2_000_000.0, "256-board incremental re-plan: {incr_us:.1} us"),
                _ => {}
            }
        }
    }
    h.table("re-plan latency, scratch vs incremental", &rows);
    h.finish();
}
