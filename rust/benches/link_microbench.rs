//! §2 micro-benchmark: inter-FPGA link vs off-chip DDR transfer time across
//! packet sizes — the measurement that motivates XFER (3× at 1 KB, 1.6× at
//! 64–128 KB).

use superlip::bench::Harness;
use superlip::platform::{FpgaSpec, LinkSpec};
use superlip::report::Table;

fn main() {
    let mut h = Harness::new("link_microbench");
    let link = LinkSpec::from_fpga(&FpgaSpec::zcu102());

    let mut t = Table::new(&["Packet", "DDR cycles", "Link cycles", "b2b speedup"]);
    for kb in [1u64, 2, 4, 8, 16, 32, 64, 128] {
        let bytes = kb * 1024;
        t.row(&[
            format!("{kb} KB"),
            link.ddr_cycles(bytes).to_string(),
            link.link_cycles(bytes).to_string(),
            format!("{:.2}x", link.b2b_speedup(bytes)),
        ]);
    }
    h.table("§2: inter-FPGA vs DDR transfer time", &t.render());
    h.record("speedup @ 1KB", link.b2b_speedup(1024), "x (paper: 3x)");
    h.record("speedup @ 64KB", link.b2b_speedup(64 * 1024), "x (paper: 1.6x)");
    h.record("speedup @ 128KB", link.b2b_speedup(128 * 1024), "x (paper: 1.6x)");

    h.measure("1M transfer-time evaluations", || {
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            acc = acc.wrapping_add(link.ddr_cycles(64 + (i % 4096)));
        }
        std::hint::black_box(acc);
    });
    h.finish();
}
