//! Control-plane drift scenario: a mid-run mix flip served by a frozen
//! static plan vs the telemetry-driven controller (EXPERIMENTS.md
//! §Control).
//!
//! Two models share a 4-board fleet; "who is hot" flips mid-run. The mix
//! is **self-calibrated** from the simulator so the contrast is
//! machine-independent and *structural*, not a tuning accident:
//!
//! * the hot model's rate is 0.55 of its 3-board service rate — a queue
//!   that is comfortably stable on 3 boards but provably UNSTABLE on the
//!   1 board the stale plan leaves it (super-linear scaling makes
//!   `s1/s3 > 3`, so `ρ₁ = 0.55·s1/s3 > 1.65`);
//! * the cold model idles at 0.25 of its 1-board service rate.
//!
//! Post-flip, the static plan's hot-model queue diverges (misses and p99
//! grow with the backlog) while the controller detects the rate breach
//! within its hysteresis window, re-plans on the observed mix, and
//! migrates lanes hitlessly — the acceptance contrast is strictly lower
//! post-flip worst-case p99 AND miss rate, plus a bounded re-plan count
//! (detect → migrate → cooldown, no flapping).

use std::time::Duration;
use superlip::bench::Harness;
use superlip::control::{run_drift_scenario, ControlConfig, DriftConfig, OnlineConfig};
use superlip::fleet::{stats_table, FleetSpec, PhaseSpec, Planner, PlannerConfig, WorkloadSpec};
use superlip::platform::FpgaSpec;
use superlip::report;

const FLEET_SIZE: usize = 4;

fn main() {
    let mut h = Harness::new("control_drift");
    let fleet = FleetSpec::homogeneous(FLEET_SIZE, FpgaSpec::zcu102());
    let pcfg = PlannerConfig::default();
    let planner = Planner::new(fleet.clone(), pcfg);

    // Self-calibrated two-model scenario (see module doc).
    let probe = |model: &str, n: usize| planner.service_ms(model, n).expect("probe") / 1e3;
    let (a1, a3) = (probe("alexnet", 1), probe("alexnet", 3));
    let (b1, b3) = (probe("squeezenet", 1), probe("squeezenet", 3));
    let hot = |s3: f64| 0.55 / s3;
    let cold = |s1: f64| 0.25 / s1;
    let mix = vec![
        WorkloadSpec::new("alexnet", hot(a3), Duration::from_secs_f64(6.0 * a1)),
        WorkloadSpec::new("squeezenet", cold(b1), Duration::from_secs_f64(6.0 * b1)),
    ];
    println!(
        "  calibration: alexnet s1 {} s3 {}  squeezenet s1 {} s3 {}",
        report::ms(a1 * 1e3),
        report::ms(a3 * 1e3),
        report::ms(b1 * 1e3),
        report::ms(b3 * 1e3)
    );
    assert!(
        0.55 * b1 / b3 > 1.0,
        "calibration: post-flip hot model must be unstable on 1 board \
         (s1/s3 = {:.2})",
        b1 / b3
    );

    let (pre_s, post_s) = if h.is_quick() { (0.5, 1.25) } else { (1.0, 2.5) };
    let phases = vec![
        PhaseSpec {
            duration_s: pre_s,
            rates_rps: vec![hot(a3), cold(b1)],
        },
        // The flip: squeezenet becomes the hot model, alexnet cools off.
        PhaseSpec {
            duration_s: post_s,
            rates_rps: vec![cold(a1), hot(b3)],
        },
    ];
    let cfg = OnlineConfig {
        seed: 2026,
        time_scale: 0.5,
        tick_s: 0.05,
        recv_timeout: Duration::from_secs(60),
        control: ControlConfig {
            drift: DriftConfig {
                // The cold model sees only ~12 arrivals per window, so one
                // noisy window must never count as evidence: 15-arrival
                // floor + 3-window hysteresis Monte-Carlos to < 1e-3
                // spurious fires across plausible service times, while the
                // flip's 4–7× surge still fires 3 ticks (0.15 s) in.
                min_arrivals: 15,
                hysteresis: 3,
                ..DriftConfig::default()
            },
            ..ControlConfig::default()
        },
        ..OnlineConfig::default()
    };
    let plan = planner.plan(&mix).expect("plan");
    h.table("initial plan (phase-0 mix)", &plan.summary());

    let run = |label: &str, controlled: bool, h: &mut Harness| {
        let out = run_drift_scenario(&fleet, pcfg, &mix, &phases, &cfg, controlled)
            .expect("scenario");
        for (pi, rows) in out.phase_stats.iter().enumerate() {
            h.table(&format!("{label} — phase {pi}"), &stats_table(rows));
        }
        for e in &out.events {
            println!("    [control] {e}");
        }
        out
    };
    let stat = run("static plan (frozen)", false, &mut h);
    let ctl = run("controlled (online re-planning)", true, &mut h);

    let (sp, cp) = (stat.worst_p99(1), ctl.worst_p99(1));
    let (sm, cm) = (stat.worst_miss_rate(1), ctl.worst_miss_rate(1));
    h.record("post-flip worst p99, static", sp, "ms");
    h.record("post-flip worst p99, controlled", cp, "ms");
    h.record("post-flip worst miss, static", sm * 100.0, "%");
    h.record("post-flip worst miss, controlled", cm * 100.0, "%");
    h.record("re-plans", ctl.replans as f64, "");
    println!(
        "  controlled beats static post-flip: p99 {}  miss {}",
        if cp < sp { "YES" } else { "NO" },
        if cm < sm { "YES" } else { "NO" }
    );

    // Acceptance: re-plan happened promptly (once the hysteresis filled —
    // no flapping storm either), and the controlled run ends the flipped
    // phase strictly better on both headline metrics.
    assert!(
        (1..=4).contains(&ctl.replans),
        "expected the flip re-plan (plus at most a few re-baselines), got {} ({:?})",
        ctl.replans,
        ctl.events
    );
    assert!(
        ctl.final_alloc != plan.allocation(),
        "the controller must have re-carved the fleet: {:?}",
        ctl.final_alloc
    );
    assert!(
        cp < sp,
        "controlled post-flip p99 {cp:.1} ms must beat static {sp:.1} ms"
    );
    assert!(
        cm < sm,
        "controlled post-flip miss {:.1}% must beat static {:.1}%",
        cm * 100.0,
        sm * 100.0
    );
    h.finish();
}
