//! Table 2: AlexNet (B=1) across platforms — mGPU / GPU / FPGA15 / ISCA17 /
//! ISLPED16 (published constants, cited) vs Super-LIP on 2 simulated
//! ZCU102s (f32 and fx16), with latency, throughput and energy efficiency.

use superlip::analytic::{check_feasible, Design, XferMode};
use superlip::bench::Harness;
use superlip::dse;
use superlip::energy::{self, PowerModel};
use superlip::model::zoo;
use superlip::platform::{gpu, FpgaSpec};
use superlip::report::{self, Table};
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let mut h = Harness::new("table2_platforms");
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = zoo::alexnet();
    let total_ops: u64 = net.conv_layers().map(|l| l.ops()).sum();

    let mut t = Table::new(&[
        "Design", "Device", "Precision", "Power(W)", "Lat(ms)", "Thr(GOPS)", "EE(GOPS/W)",
    ]);
    for b in gpu::table2_baselines() {
        t.row(&[
            b.name.into(),
            b.device.into(),
            b.precision.into(),
            b.power_w.map(|p| format!("{p:.2}")).unwrap_or("-".into()),
            if b.latency_ms.0 == b.latency_ms.1 {
                format!("{:.2}", b.latency_ms.0)
            } else {
                format!("{:.1}-{:.1}", b.latency_ms.0, b.latency_ms.1)
            },
            format!("{:.2}", b.gops),
            b.ee_gops_per_w
                .map(|e| format!("{e:.2}"))
                .unwrap_or("-".into()),
        ]);
    }

    // Super-LIP rows: 2 FPGAs, f32 and fx16 (Figure-15 tilings).
    let mut superlip_ms = Vec::new();
    for d in [
        Design::float32(64, 7, 7, 14),
        Design::fixed16(128, 10, 7, 14),
    ] {
        let k_max = net.conv_layers().map(|l| l.k).max().unwrap();
        let usage = check_feasible(&d, &fpga, k_max).unwrap();
        let (f, _) = dse::best_factors(&net, &d, &fpga, 2, XferMode::Xfer);
        let sim = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer);
        let ms = d.precision.cycles_to_ms(sim.cycles);
        let gops = energy::gops(total_ops, sim.cycles, d.precision);
        let watts = PowerModel::new(2).watts(&d, &usage);
        superlip_ms.push(ms);
        t.row(&[
            "Super-LIP".into(),
            "2xZCU102 (sim)".into(),
            d.precision.name().into(),
            format!("{watts:.2}"),
            report::ms(ms),
            format!("{gops:.2}"),
            format!("{:.2}", gops / watts),
        ]);
    }
    h.table("Table 2: cross-platform comparison (AlexNet, batch 1)", &t.render());
    h.record("Super-LIP f32 latency", superlip_ms[0], "ms (paper: 10.13)");
    h.record("Super-LIP fx16 latency", superlip_ms[1], "ms (paper: 2.27)");
    println!(
        "  shape check: fx16 Super-LIP fastest of all platforms: {}",
        if superlip_ms[1] < 5.1 { "REPRODUCED" } else { "NOT reproduced" }
    );

    let d = Design::fixed16(128, 10, 7, 14);
    h.measure("simulate 2-FPGA AlexNet (fx16)", || {
        let (f, _) = dse::best_factors(&net, &d, &fpga, 2, XferMode::Xfer);
        std::hint::black_box(simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer));
    });
    h.finish();
}
