//! Serving hot-path microbench (EXPERIMENTS.md §Hotpath): drive the full
//! `submit_to_class` → route → batch → complete pipeline against a **null
//! backend** (infer returns instantly) so the measured cost is the serving
//! machinery itself — the lock-free route snapshot, the sharded per-class
//! queues, the condvar handshake, the histogram metrics — not compute.
//!
//! Closed-loop load: each submitter keeps a bounded window of in-flight
//! requests (submit one, and once the window is full, reap the oldest
//! response), so the pipeline stays saturated without unbounded queues.
//! Reported metrics, both gated by CI against `BENCH_serving.json`:
//!
//! * **ns/request** (lower is better) — wall nanoseconds per completed
//!   request, first submit to last response;
//! * **rps/core** (higher is better) — completed requests per second
//!   divided by the threads doing the work (submitters + lane workers),
//!   the honest per-core figure that a super-linear claim must not hide
//!   behind added parallelism.
//!
//! Tail percentiles (p99.9/p99.99) come from the server's bounded HDR
//! histograms and are recorded informationally — they prove the metrics
//! path survives million-RPS accounting without unbounded Vec growth.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};
use superlip::bench::Harness;
use superlip::fleet::SloClass;
use superlip::serving::{
    BackendFactory, BatcherConfig, InferBackend, LaneSpec, RoutePolicy, Server, ServerConfig,
};

/// The null backend: one scalar in, one logit out, no work. `max_batch`
/// is wide so the batcher's coalescing (not the backend) sets batch size.
struct NullBackend;

impl InferBackend for NullBackend {
    fn image_elems(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn infer(&self, _images: &[f32], n: usize) -> superlip::Result<Vec<f32>> {
        Ok(vec![0.0; n])
    }
}

const MODEL: &str = "null";
const LANES: usize = 2;
const WORKERS_PER_LANE: usize = 2;
const SUBMITTERS: usize = 3;
/// In-flight window per submitter — deep enough to saturate, bounded so
/// queues stay small and latency stays meaningful.
const PIPELINE: usize = 64;

fn lane() -> LaneSpec {
    LaneSpec {
        model: MODEL.into(),
        factories: (0..WORKERS_PER_LANE)
            .map(|_| {
                Box::new(|| Ok(Box::new(NullBackend) as Box<dyn InferBackend>)) as BackendFactory
            })
            .collect(),
        batcher: BatcherConfig {
            max_batch: 32,
            // No coalescing wait: a null backend has nothing to amortize,
            // so the bench measures queue mechanics, not sleep.
            window: Duration::from_millis(0),
            ..BatcherConfig::default()
        },
    }
}

/// One saturated closed-loop run; returns (completed requests, wall secs).
fn drive(server: &Server, per_submitter: usize) -> (u64, f64) {
    let completed = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..SUBMITTERS {
            let completed = &completed;
            s.spawn(move || {
                let deadline = Duration::from_secs(5);
                // Rotate classes so the sharded sub-queues all see traffic.
                let class = match t % 3 {
                    0 => SloClass::Gold,
                    1 => SloClass::Silver,
                    _ => SloClass::BestEffort,
                };
                let mut inflight = std::collections::VecDeque::with_capacity(PIPELINE);
                let mut done = 0u64;
                for _ in 0..per_submitter {
                    let rx = server
                        .submit_to_class(MODEL, vec![0.0], deadline, class)
                        .expect("null lane accepts");
                    inflight.push_back(rx);
                    if inflight.len() >= PIPELINE {
                        let oldest = inflight.pop_front().unwrap();
                        oldest.recv().expect("response");
                        done += 1;
                    }
                }
                for rx in inflight {
                    rx.recv().expect("response");
                    done += 1;
                }
                completed.fetch_add(done, Ordering::Relaxed);
            });
        }
    });
    (completed.load(Ordering::Relaxed), t0.elapsed().as_secs_f64())
}

fn main() {
    let mut h = Harness::new("serving_hotpath");
    let per_submitter: usize = if h.is_quick() { 20_000 } else { 200_000 };

    let server = Server::start_plan(
        (0..LANES).map(|_| lane()).collect(),
        ServerConfig {
            policy: RoutePolicy::LeastOutstanding,
            ..ServerConfig::default()
        },
    );

    // Warmup: page in the pipeline, then reset metrics so the measured
    // window is steady-state only.
    drive(&server, per_submitter / 10);
    server.metrics().reset();

    let (n, wall) = drive(&server, per_submitter);
    assert_eq!(n as usize, SUBMITTERS * per_submitter, "exactly-one-response");

    let throughput = n as f64 / wall;
    let cores = (SUBMITTERS + LANES * WORKERS_PER_LANE) as f64;
    let ns_per_req = wall * 1e9 / n as f64;
    h.record("hot path, submit→complete", ns_per_req, "ns/req");
    h.record("hot path throughput per core", throughput / cores, "rps/core");
    h.record("hot path aggregate throughput", throughput, "req/s");

    // Tail latencies from the bounded histograms (informational: the
    // p99.9/p99.99 upgrade the HDR buckets bought, within 1.5625%).
    let m = server.metrics();
    if let Some(l) = m.latency_stats() {
        h.record("end-to-end p50", l.p50_ms, "lat-ms");
        h.record("end-to-end p99.9", l.p999_ms, "lat-ms");
        h.record("end-to-end p99.99", l.p9999_ms, "lat-ms");
    }
    h.record("mean batch", m.mean_batch(), "req");

    server.shutdown();
    h.finish();
}
