//! END-TO-END DRIVER: real-time DNN inference through the full three-layer
//! stack — the deliverable that proves all layers compose.
//!
//!   L1  Pallas tiled conv kernel  ┐ compiled once by `make artifacts`
//!   L2  JAX TinyCNN forward       ┘ into artifacts/*.hlo.txt
//!   L3  this binary: PJRT-loads the artifacts, routes a Poisson stream of
//!       image requests through the deadline-aware batcher to a worker
//!       pool, and reports latency percentiles + throughput. It also
//!       plans the same model's AlexNet-class big sibling on the simulated
//!       2-FPGA ZCU102 cluster to show the deployment path.
//!
//! Requires `make artifacts` first (skips gracefully if missing).
//!
//! Run: `cargo run --release --example realtime_serving`

use std::time::{Duration, Instant};
use superlip::coordinator::SuperLip;
use superlip::model::zoo;
use superlip::platform::Precision;
use superlip::runtime::{ModelExecutor, PjrtRuntime};
use superlip::serving::{BackendFactory, InferBackend, Server, ServerConfig};
use superlip::util::SplitMix64;

const IMAGE_ELEMS: usize = 3 * 32 * 32;

fn main() -> superlip::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }

    // --- Functional check: PJRT output matches across batch sizes.
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exec = ModelExecutor::load(&rt, &dir)?;
    let mut rng = SplitMix64::new(7);
    let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.signed_unit()).collect();
    let single = exec.infer(&img, 1)?;
    let mut two = img.clone();
    two.extend_from_slice(&img);
    let batched = exec.infer(&two, 2)?;
    let classes = exec.classes;
    let dev: f32 = (0..classes)
        .map(|c| (single[c] - batched[c]).abs())
        .fold(0.0, f32::max);
    println!(
        "batch-consistency check: max |logit(b1) - logit(b2)| = {dev:.2e} (classes: {classes})"
    );
    assert!(dev < 1e-3, "batching must not change results");
    drop(exec);
    drop(rt);

    // --- Serve a Poisson request stream through the batcher + worker pool.
    let replicas = 2usize;
    let factories: Vec<BackendFactory> = (0..replicas)
        .map(|_| {
            let dir = dir.clone();
            Box::new(move || {
                let rt = PjrtRuntime::cpu()?;
                Ok(Box::new(ModelExecutor::load(&rt, &dir)?) as Box<dyn InferBackend>)
            }) as BackendFactory
        })
        .collect();
    let mut cfg = ServerConfig::default();
    cfg.batcher.max_batch = 4;
    cfg.batcher.window = Duration::from_millis(2);
    cfg.default_deadline = Duration::from_millis(50);
    let server = Server::start(factories, cfg);

    // Warmup (PJRT compiles lazily in each worker), then measure.
    server.submit(vec![0.0; IMAGE_ELEMS])?.recv().unwrap();
    server.metrics().reset();

    let n_requests = 400usize;
    let rate_rps = 400.0;
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for _ in 0..n_requests {
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|_| rng.signed_unit()).collect();
        rxs.push(server.submit(img)?);
        std::thread::sleep(Duration::from_secs_f64(rng.exp(1.0 / rate_rps)));
    }
    for rx in rxs {
        rx.recv().expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    let s = m.latency_stats().unwrap();
    println!("\n=== end-to-end serving (TinyCNN over PJRT, {replicas} replicas) ===");
    println!("  requests:        {}", m.completed());
    println!("  offered load:    {rate_rps:.0} req/s (Poisson)");
    println!("  throughput:      {:.1} req/s", m.completed() as f64 / wall);
    println!(
        "  latency p50/p99/p99.9: {:.2} / {:.2} / {:.2} ms",
        s.p50_ms, s.p99_ms, s.p999_ms
    );
    println!("  mean batch:      {:.2}", m.mean_batch());
    println!("  deadline misses: {}/{}", m.deadline_misses(), m.completed());

    // --- Deployment path: the production-size sibling on the simulated
    //     ZCU102 cluster (what the paper's testbed would run).
    let slip = SuperLip::default();
    let plan = slip.plan(&zoo::alexnet(), Precision::Fixed16, 2)?;
    println!("\n=== simulated 2-FPGA ZCU102 deployment of AlexNet (fx16) ===");
    println!("{}", plan.summary());
    Ok(())
}
