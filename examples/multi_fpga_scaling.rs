//! Figure 15 reproduction as a runnable example: scale all four CNNs from
//! 1 to 16 FPGAs and print latency / speedup / energy-efficiency curves.
//!
//! Run: `cargo run --release --example multi_fpga_scaling`

use superlip::analytic::{check_feasible, Design, XferMode};
use superlip::dse;
use superlip::energy::{self, PowerModel};
use superlip::model::zoo;
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let sizes = [1u64, 2, 3, 4, 6, 8, 9, 12, 16];

    // Figure 15's tilings: ⟨Tm,Tn⟩ per network (fx16), with the
    // cross-layer row tiles ⟨7,14⟩ (Table 1).
    let tilings = [
        ("AlexNet", Design::fixed16(128, 10, 7, 14)),
        ("SqueezeNet", Design::fixed16(64, 16, 7, 14)),
        ("VGG16", Design::fixed16(64, 25, 7, 14)),
        ("YOLO", Design::fixed16(64, 25, 7, 14)),
    ];

    for (name, d) in tilings {
        let net = zoo::by_name(name).unwrap();
        let k_max = net.conv_layers().map(|l| l.k).max().unwrap();
        let usage = check_feasible(&d, &fpga, k_max).expect("figure-15 tiling feasible");
        let total_ops: u64 = net.conv_layers().map(|l| l.ops()).sum();

        let mut t = Table::new(&["FPGAs", "Partition", "ms", "Speedup", "GOPS", "GOPS/W", "EE vs 1"]);
        let mut single_cycles = 0u64;
        let mut single_ee = 0.0f64;
        for &n in &sizes {
            let (f, _) = dse::best_factors(&net, &d, &fpga, n, XferMode::Xfer);
            let sim = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer);
            if n == 1 {
                single_cycles = sim.cycles;
            }
            let gops = energy::gops(total_ops, sim.cycles, d.precision);
            let watts = PowerModel::new(n).watts(&d, &usage);
            let ee = gops / watts;
            if n == 1 {
                single_ee = ee;
            }
            t.row(&[
                n.to_string(),
                f.to_string(),
                report::ms(d.precision.cycles_to_ms(sim.cycles)),
                report::speedup(single_cycles as f64 / sim.cycles as f64),
                report::gops(gops),
                format!("{ee:.2}"),
                report::pct(ee / single_ee - 1.0),
            ]);
        }
        println!("== {name} (fx16, design {d}) ==");
        println!("{}", t.render());
    }
    println!("Paper reference points (Figure 15): AlexNet 5.63 ms → 0.31 ms (17.95x @16);");
    println!("SqueezeNet 6.69 → 0.45 ms (14.75x); YOLO 126.6 → 4.53 ms (27.93x @16).");
}
