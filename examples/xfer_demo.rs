//! XFER walk-through (Figure 3): a weight-shared 2-FPGA partition where
//! distributing the weights and exchanging them over the inter-FPGA link
//! cuts the pipeline cycle time `Lat2` by ~40%.
//!
//! Run: `cargo run --release --example xfer_demo`

use superlip::analytic::{xfer_layer_latency, Design, XferMode};
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::{FpgaSpec, LinkSpec};

fn main() {
    let fpga = FpgaSpec::zcu102();

    // The §2 micro-benchmark that motivates XFER.
    let link = LinkSpec::from_fpga(&fpga);
    println!("inter-FPGA vs DDR transfer speedup (paper §2):");
    for kb in [1u64, 4, 16, 64, 128] {
        println!("  {:>4} KB packets: {:.2}x", kb, link.b2b_speedup(kb * 1024));
    }

    // A weight-bound layer + design (the Figure 3 setting).
    let net = zoo::alexnet();
    let layer = &net.layers[1]; // conv2: 5×5 kernels, heavy weights
    let d = Design::fixed16(128, 10, 7, 14);
    let f = Factors::new(1, 2, 1, 1); // row partition → weights shared

    let base = xfer_layer_latency(layer, &d, &f, &fpga, XferMode::Baseline);
    let xfer = xfer_layer_latency(layer, &d, &f, &fpga, XferMode::Xfer);

    println!("\nlayer {} on 2 FPGAs ({}):", layer.name, f);
    println!(
        "  workload-balance baseline: Lat1={} tW={} Lat2={}",
        base.worst.lat1, base.worst.t_w, base.worst.lat2
    );
    println!(
        "  XFER:                      Lat1={} tW={} (b2b {}) Lat2={}",
        xfer.worst.lat1, xfer.worst.t_w, xfer.worst.t_b2b, xfer.worst.lat2
    );
    let gain = 1.0 - xfer.worst.lat2 as f64 / base.worst.lat2 as f64;
    println!(
        "  pipeline cycle time reduced {:.2}% (Figure 3 reports 39.65%: 2953 → 1782)",
        gain * 100.0
    );
    println!(
        "  layer latency: {} → {} cycles ({:.2}x)",
        base.worst.lat,
        xfer.worst.lat,
        base.worst.lat as f64 / xfer.worst.lat as f64
    );
    println!(
        "  eq 22 bandwidth check: d_row={} d_col={} ok={}",
        xfer.d_row, xfer.d_col, xfer.bandwidth_ok
    );
}
