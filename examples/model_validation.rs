//! Figure 14 / Table 4 reproduction: compare the paper's accurate model and
//! the FPGA15 roofline model against the cycle-level simulator ("on-board"
//! stand-in) on single- and 2-FPGA designs.
//!
//! Run: `cargo run --release --example model_validation`

use superlip::analytic::{self, baseline, detect, Design, XferMode};
use superlip::model::zoo;
use superlip::partition::Factors;
use superlip::platform::FpgaSpec;
use superlip::report::{self, Table};
use superlip::sim::{simulate_network, SimConfig};

fn main() {
    let fpga = FpgaSpec::zcu102();
    let cfg = SimConfig::zcu102(&fpga);
    let net = {
        let alex = zoo::alexnet();
        superlip::model::Network::new("alexnet-conv5", vec![alex.layers[4].clone()])
    };
    let full = zoo::alexnet();
    let bus_words = fpga.mem_bus_bits / 32;

    // Figure 14's four designs: three single-FPGA f32 designs of growing
    // MAC count, plus the 2-FPGA design (which [14] cannot model at all).
    let designs = [(12u64, 16u64), (10, 22), (8, 32)];

    let mut t = Table::new(&[
        "Design", "FPGAs", "[14] kcyc", "Ours kcyc", "Sim kcyc", "[14] dev", "Our dev",
    ]);
    for (tm, tn) in designs {
        let d = Design::float32(tm, tn, 13, 13);
        let ours: u64 = analytic::network_latency(&net, &d);
        let theirs: u64 = net
            .conv_layers()
            .map(|l| baseline::fpga15_latency(l, &d, bus_words).cycles)
            .sum();
        let sim = simulate_network(&net, &d, &Factors::single(), &fpga, &cfg, XferMode::Xfer)
            .cycles;
        t.row(&[
            format!("<{tm},{tn}>"),
            "1".into(),
            report::kcycles(theirs),
            report::kcycles(ours),
            report::kcycles(sim),
            report::pct((sim as f64 - theirs as f64).abs() / sim as f64),
            report::pct((sim as f64 - ours as f64).abs() / sim as f64),
        ]);
    }
    // 2-FPGA point: ours vs sim only ([14] has no multi-FPGA story).
    let d = Design::float32(8, 32, 13, 13);
    let f = Factors::new(1, 1, 1, 2);
    let ours2: u64 = analytic::xfer_network_latency(&net, &d, &f, &fpga, XferMode::Xfer);
    let sim2 = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer).cycles;
    t.row(&[
        "<8,32> Pm=2".into(),
        "2".into(),
        "n/a".into(),
        report::kcycles(ours2),
        report::kcycles(sim2),
        "n/a".into(),
        report::pct((sim2 as f64 - ours2 as f64).abs() / sim2 as f64),
    ]);
    println!("{}", t.render());

    // Table 4-style bottleneck detection + alleviation (full AlexNet).
    let net = full;
    println!("Bottleneck detection (Corollary 1) and XFER alleviation:");
    for (label, d, f) in [
        ("A <8,32> f32 single", Design::float32(8, 32, 13, 13), Factors::single()),
        ("B = A + XFER Pm=2", Design::float32(8, 32, 13, 13), Factors::new(1, 1, 1, 2)),
        ("C <64,20> fx16 single", Design::fixed16(64, 20, 13, 13).with_streams(8, 2, 8), Factors::single()),
        ("D = C + XFER Pr=2", Design::fixed16(64, 20, 13, 13).with_streams(8, 2, 8), Factors::new(1, 2, 1, 1)),
    ] {
        let worst = net
            .conv_layers()
            .map(|l| analytic::xfer_layer_latency(l, &d, &f, &fpga, XferMode::Xfer))
            .max_by_key(|c| c.worst.lat)
            .unwrap();
        let sim = simulate_network(&net, &d, &f, &fpga, &cfg, XferMode::Xfer).cycles;
        println!(
            "  {label:<24} bound={:<10} sim={} kcycles",
            detect(&worst.worst).label(),
            sim / 1000
        );
    }
    println!("\nPaper: designs A/C are IFM-/weight-bound; XFER moves both to compute-bound\nwith 3.30x / 3.43x speedups (Table 4).");
}
