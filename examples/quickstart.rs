//! Quickstart: model one CNN layer, search its design space, detect the
//! bottleneck, and plan a 2-FPGA XFER deployment.
//!
//! Run: `cargo run --release --example quickstart`

use superlip::analytic::{detect, layer_latency, Design};
use superlip::coordinator::SuperLip;
use superlip::dse;
use superlip::model::zoo;
use superlip::platform::{FpgaSpec, Precision};

fn main() -> superlip::Result<()> {
    // 1. A workload: AlexNet conv3 = ⟨B,M,N,R,C,K⟩ = ⟨1,384,256,13,13,3⟩.
    let net = zoo::alexnet();
    let layer = &net.layers[2];
    println!(
        "layer {}: {} MACs, {} weights",
        layer.name,
        layer.macs(),
        layer.weight_elems()
    );

    // 2. Evaluate a hand-written design with the paper's model (eqs 8–14).
    let d = Design::fixed16(64, 24, 13, 13);
    let ll = layer_latency(layer, &d);
    println!(
        "design {d}: Lat1={} Lat2={} total={} cycles ({:.3} ms) — bottleneck: {}",
        ll.lat1,
        ll.lat2,
        ll.lat,
        d.precision.cycles_to_ms(ll.lat),
        detect(&ll).label()
    );

    // 3. Let the DSE find the per-layer optimum on a ZCU102.
    let fpga = FpgaSpec::zcu102();
    let (best, best_ll, stats) = dse::best_layer_design(layer, &fpga, Precision::Fixed16);
    println!(
        "DSE optimum {best}: {} cycles ({} designs evaluated, {} pruned)",
        best_ll.lat, stats.evaluated, stats.infeasible
    );

    // 4. Plan the full network on 1 vs 2 FPGAs (XFER).
    let slip = SuperLip::default();
    let p1 = slip.plan(&net, Precision::Fixed16, 1)?;
    let p2 = slip.plan(&net, Precision::Fixed16, 2)?;
    println!("\n--- 1 FPGA (best single design) ---\n{}", p1.summary());
    println!("--- 2 FPGAs (XFER, co-optimized) ---\n{}", p2.summary());

    // The paper's Figure 15 protocol measures speedup with the SAME design
    // at both cluster sizes; against independently re-optimized designs the
    // bar is higher (a well-tuned single FPGA is compute-bound).
    let p1_same = slip.plan_with_design(&net, p2.design, 1)?;
    let paper_protocol = p1_same.sim_cycles as f64 / p2.sim_cycles as f64;
    let strict = p1.sim_cycles as f64 / p2.sim_cycles as f64;
    println!(
        "\nspeedup with 2 FPGAs, same design (paper's Fig.15 protocol): {paper_protocol:.2}x ({})",
        if paper_protocol > 2.0 { "SUPER-linear" } else { "sub-linear" }
    );
    println!(
        "speedup vs independently re-optimized single FPGA:           {strict:.2}x"
    );

    // With the paper's published Figure 15(a) tiling (⟨128,10⟩, weight-
    // bound on one FPGA) the same-design speedup is super-linear — XFER
    // relieves the weight stream while the trips halve.
    let fig15 = Design::fixed16(128, 10, 7, 14);
    let f1 = slip.plan_with_design(&net, fig15, 1)?;
    let f2 = slip.plan_with_design(&net, fig15, 2)?;
    println!(
        "speedup with the paper's Fig.15 tiling <128,10>:              {:.2}x (paper: 2.54x)",
        f1.sim_cycles as f64 / f2.sim_cycles as f64
    );
    Ok(())
}
