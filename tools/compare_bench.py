#!/usr/bin/env python3
"""CI perf-trajectory gate: compare a bench run's JSON output (emitted by
the bench harness via `--json <path>` / `SUPERLIP_BENCH_JSON`) against the
baseline JSON checked into the repo root (BENCH_fleet.json,
BENCH_control.json, BENCH_energy.json).

Usage:
    python3 tools/compare_bench.py <baseline.json> <current.json>

Rules (per metric listed in the BASELINE):

* unit "ms" (latencies): FAIL when
      current > baseline * (1 + rel) + 1.0 ms
* unit "W" (fleet watts) / "J/inf" (energy per inference): FAIL when
      current > baseline * (1 + rel) + 0.5
* unit "ns/req" (hot-path cost per request): FAIL when
      current > baseline * (1 + rel) + 50.0 ns
* unit "us/replan" (control-plane re-plan latency): FAIL when
      current > baseline * (1 + rel) + 50.0 us
* unit "%" (miss rates): FAIL when
      current > baseline + max(2.0, rel * 100 * baseline / 100) points
  (i.e. an absolute 2-point floor so near-zero baselines are not
  infinitely strict)
* unit "rps/core" (hot-path throughput per core — HIGHER is better):
  FAIL when
      current < baseline * (1 - rel) - 1000.0
* other units: informational only.

Metrics present in the CURRENT run but missing from the baseline are
listed with a WARNING (not a failure) so a bench can grow new metrics —
and a baseline FILE that does not exist yet warns and passes, so a new
bench can land one PR before its baseline is seeded.

`rel` defaults to 0.10 (the ">10% regression" contract) and can be
overridden per metric with a `"rel"` key in the baseline entry — used for
provisional baselines seeded from the analytic event-sim port rather than
a real CI run (see the `_comment` in each baseline file). Improvements
never fail (in the metric's good direction), and the script prints a
refreshed baseline block so maintainers can tighten provisional entries
once real runner numbers exist.

Exit code: 0 = within tolerance (or baseline missing), 1 = regression,
2 = usage/format error.
"""
import json
import os
import sys

# Lower-is-worse units gated multiplicatively, with their absolute slack.
GATED_REL = {"ms": 1.0, "W": 0.5, "J/inf": 0.5, "ns/req": 50.0, "us/replan": 50.0}
# Higher-is-better units (throughputs): a DROP past rel fails, with an
# absolute slack floor so tiny baselines are not infinitely strict.
GATED_HIGHER = {"rps/core": 1000.0}


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"compare_bench: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    base_path, cur_path = sys.argv[1], sys.argv[2]
    if not os.path.exists(base_path):
        # A brand-new bench may land before its baseline is seeded: warn
        # loudly, print the current metrics as a seeding aid, and pass.
        cur_doc = load(cur_path)
        print(
            f"compare_bench: WARNING: baseline {base_path} does not exist — "
            "nothing gated this run. Seed it from the block below."
        )
        print(json.dumps(cur_doc.get("metrics", {}), indent=2))
        sys.exit(0)
    base_doc, cur_doc = load(base_path), load(cur_path)
    base = base_doc.get("metrics", {})
    cur = cur_doc.get("metrics", {})
    if base_doc.get("quick") is not None and cur_doc.get("quick") is not None:
        if base_doc["quick"] != cur_doc["quick"]:
            print(
                f"compare_bench: WARNING: baseline quick={base_doc['quick']} "
                f"vs current quick={cur_doc['quick']} — numbers are not "
                "directly comparable; gating anyway."
            )

    failures, rows = [], []
    for label, b in base.items():
        if label.startswith("_"):
            continue
        bv, unit = b.get("value"), b.get("unit", "")
        rel = float(b.get("rel", 0.10))
        c = cur.get(label)
        if c is None or c.get("value") is None:
            failures.append(f"{label}: missing from current run")
            rows.append((label, bv, None, unit, "MISSING"))
            continue
        cv = c["value"]
        if bv is None:
            rows.append((label, bv, cv, unit, "seed-me"))
            continue
        if unit in GATED_REL:
            limit = bv * (1.0 + rel) + GATED_REL[unit]
            verdict = "FAIL" if cv > limit else "ok"
        elif unit in GATED_HIGHER:
            limit = bv * (1.0 - rel) - GATED_HIGHER[unit]
            verdict = "FAIL" if cv < limit else "ok"
        elif unit == "%":
            limit = bv + max(2.0, rel * bv)
            verdict = "FAIL" if cv > limit else "ok"
        else:
            limit, verdict = None, "info"
        if verdict == "FAIL":
            direction = "fell below" if unit in GATED_HIGHER else "exceeds"
            failures.append(
                f"{label}: {cv:.3f}{unit} {direction} baseline {bv:.3f}{unit} "
                f"(limit {limit:.3f}{unit}, rel {rel:.0%})"
            )
        rows.append((label, bv, cv, unit, verdict))

    # Metrics the current run reports but the baseline does not know —
    # warn so they get seeded instead of silently never gating.
    unbaselined = [
        label
        for label in cur
        if not label.startswith("_") and label not in base
    ]
    for label in unbaselined:
        cv = (cur.get(label) or {}).get("value")
        rows.append((label, None, cv, (cur.get(label) or {}).get("unit", ""), "unbased"))

    name = base_doc.get("bench", "?")
    print(f"perf gate: {name} ({cur_path} vs {base_path})")
    for label, bv, cv, unit, verdict in rows:
        btxt = "-" if bv is None else f"{bv:.3f}"
        ctxt = "-" if cv is None else f"{cv:.3f}"
        print(f"  [{verdict:>7}] {label:<44} base {btxt:>10} {unit:<5} now {ctxt:>10} {unit}")
    if unbaselined:
        print(
            "compare_bench: WARNING: current run has metrics the baseline "
            f"lacks (not gated): {unbaselined} — add them to {base_path} to gate."
        )

    # Refreshed baseline block for maintainers tightening provisional seeds.
    refreshed = {
        label: {"value": (cur.get(label) or {}).get("value"), "unit": b.get("unit", "")}
        for label, b in base.items()
        if not label.startswith("_")
    }
    print("refreshed baseline metrics (paste into the BENCH_*.json to tighten):")
    print(json.dumps(refreshed, indent=2))

    if failures:
        print("\nPERF REGRESSION:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("\nperf gate passed")


if __name__ == "__main__":
    main()
