"""CI-gate correctness: tools/compare_bench.py is the only thing standing
between a perf regression and a green check, so its verdict logic gets the
same test treatment as the code it gates. Pure stdlib (subprocess + tmp
JSON files) — no jax/numpy needed, so the CI lint job can run this file
alone."""

import json
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[2]
SCRIPT = REPO_ROOT / "tools" / "compare_bench.py"


def _doc(metrics, bench="testbench", quick=True):
    return {"bench": bench, "quick": quick, "metrics": metrics}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return p


def _run(*args):
    return subprocess.run(
        [sys.executable, str(SCRIPT)] + [str(a) for a in args],
        capture_output=True,
        text=True,
    )


def test_within_tolerance_passes(tmp_path):
    base = _write(
        tmp_path,
        "base.json",
        _doc({"p99": {"value": 10.0, "unit": "ms"}, "miss": {"value": 5.0, "unit": "%"}}),
    )
    cur = _write(
        tmp_path,
        "cur.json",
        _doc({"p99": {"value": 10.5, "unit": "ms"}, "miss": {"value": 6.0, "unit": "%"}}),
    )
    r = _run(base, cur)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "perf gate passed" in r.stdout


def test_regression_detected(tmp_path):
    # 10 ms baseline at rel 0.10 → limit 10*1.1 + 1.0 = 12 ms; 50 ms fails.
    base = _write(tmp_path, "base.json", _doc({"p99": {"value": 10.0, "unit": "ms"}}))
    cur = _write(tmp_path, "cur.json", _doc({"p99": {"value": 50.0, "unit": "ms"}}))
    r = _run(base, cur)
    assert r.returncode == 1
    assert "PERF REGRESSION" in r.stdout
    assert "p99" in r.stdout


def test_percent_unit_has_two_point_floor(tmp_path):
    # Near-zero % baselines get an absolute 2-point floor: 0.5 → 2.0 is
    # ok, 0.5 → 3.0 fails.
    base = _write(tmp_path, "base.json", _doc({"miss": {"value": 0.5, "unit": "%"}}))
    ok = _write(tmp_path, "ok.json", _doc({"miss": {"value": 2.0, "unit": "%"}}))
    bad = _write(tmp_path, "bad.json", _doc({"miss": {"value": 3.0, "unit": "%"}}))
    assert _run(base, ok).returncode == 0
    assert _run(base, bad).returncode == 1


def test_improvements_never_fail(tmp_path):
    base = _write(tmp_path, "base.json", _doc({"p99": {"value": 10.0, "unit": "ms"}}))
    cur = _write(tmp_path, "cur.json", _doc({"p99": {"value": 1.0, "unit": "ms"}}))
    assert _run(base, cur).returncode == 0


def test_unbaselined_current_metric_warns_but_passes(tmp_path):
    base = _write(tmp_path, "base.json", _doc({"p99": {"value": 10.0, "unit": "ms"}}))
    cur = _write(
        tmp_path,
        "cur.json",
        _doc(
            {
                "p99": {"value": 10.0, "unit": "ms"},
                "brand new metric": {"value": 7.0, "unit": "ms"},
            }
        ),
    )
    r = _run(base, cur)
    assert r.returncode == 0
    assert "WARNING" in r.stdout
    assert "brand new metric" in r.stdout


def test_missing_baseline_file_warns_and_passes(tmp_path):
    cur = _write(tmp_path, "cur.json", _doc({"p99": {"value": 10.0, "unit": "ms"}}))
    r = _run(tmp_path / "nonexistent.json", cur)
    assert r.returncode == 0
    assert "WARNING" in r.stdout
    assert "does not exist" in r.stdout


def test_baseline_metric_missing_from_current_fails(tmp_path):
    # A metric the baseline gates MUST be reported — a silently dropped
    # metric is indistinguishable from hiding a regression.
    base = _write(tmp_path, "base.json", _doc({"p99": {"value": 10.0, "unit": "ms"}}))
    cur = _write(tmp_path, "cur.json", _doc({"other": {"value": 1.0, "unit": "ms"}}))
    r = _run(base, cur)
    assert r.returncode == 1
    assert "missing from current run" in r.stdout


def test_underscore_labels_are_skipped(tmp_path):
    # `_comment` blocks in the checked-in baselines are documentation, not
    # metrics — even a null value must not gate.
    base = _write(
        tmp_path,
        "base.json",
        _doc(
            {
                "_comment": {"value": None, "unit": "", "note": "doc"},
                "p99": {"value": 10.0, "unit": "ms"},
            }
        ),
    )
    cur = _write(tmp_path, "cur.json", _doc({"p99": {"value": 10.0, "unit": "ms"}}))
    r = _run(base, cur)
    assert r.returncode == 0
    assert "_comment" not in [line.split()[1] for line in r.stdout.splitlines() if line.startswith("  [")]


def test_per_metric_rel_override(tmp_path):
    # rel 1.0 widens the ms gate to 2x + 1 ms: 19 ms passes, 22 ms fails.
    base = _write(
        tmp_path, "base.json", _doc({"p99": {"value": 10.0, "unit": "ms", "rel": 1.0}})
    )
    ok = _write(tmp_path, "ok.json", _doc({"p99": {"value": 19.0, "unit": "ms"}}))
    bad = _write(tmp_path, "bad.json", _doc({"p99": {"value": 22.0, "unit": "ms"}}))
    assert _run(base, ok).returncode == 0
    assert _run(base, bad).returncode == 1


def test_ns_per_request_gates_lower_is_worse(tmp_path):
    # 2000 ns at rel 0.10 → limit 2000*1.1 + 50 = 2250 ns; 2200 passes,
    # 2400 fails, and a big improvement sails through.
    base = _write(
        tmp_path, "base.json", _doc({"hotpath": {"value": 2000.0, "unit": "ns/req"}})
    )
    ok = _write(tmp_path, "ok.json", _doc({"hotpath": {"value": 2200.0, "unit": "ns/req"}}))
    bad = _write(tmp_path, "bad.json", _doc({"hotpath": {"value": 2400.0, "unit": "ns/req"}}))
    fast = _write(tmp_path, "fast.json", _doc({"hotpath": {"value": 100.0, "unit": "ns/req"}}))
    assert _run(base, ok).returncode == 0
    r = _run(base, bad)
    assert r.returncode == 1
    assert "exceeds baseline" in r.stdout
    assert _run(base, fast).returncode == 0


def test_us_per_replan_gates_lower_is_worse(tmp_path):
    # The re-plan latency unit: 100 us at rel 0.10 → limit 100*1.1 + 50 =
    # 160 us; 150 passes, 200 fails, and a faster re-plan never fails. The
    # checked-in BENCH_replan.json seeds use a wide provisional rel, but
    # the unit must gate at default rel like the other lower-better units.
    base = _write(
        tmp_path, "base.json", _doc({"replan": {"value": 100.0, "unit": "us/replan"}})
    )
    ok = _write(tmp_path, "ok.json", _doc({"replan": {"value": 150.0, "unit": "us/replan"}}))
    bad = _write(tmp_path, "bad.json", _doc({"replan": {"value": 200.0, "unit": "us/replan"}}))
    fast = _write(tmp_path, "fast.json", _doc({"replan": {"value": 5.0, "unit": "us/replan"}}))
    assert _run(base, ok).returncode == 0
    r = _run(base, bad)
    assert r.returncode == 1
    assert "exceeds baseline" in r.stdout
    assert _run(base, fast).returncode == 0


def test_rps_per_core_gates_higher_is_better(tmp_path):
    # 100k rps/core at rel 0.10 → floor 100000*0.9 - 1000 = 89000; a drop
    # to 95k passes, 80k fails (with a direction-aware message), and a
    # throughput GAIN never fails.
    base = _write(
        tmp_path, "base.json", _doc({"tput": {"value": 100000.0, "unit": "rps/core"}})
    )
    ok = _write(tmp_path, "ok.json", _doc({"tput": {"value": 95000.0, "unit": "rps/core"}}))
    bad = _write(tmp_path, "bad.json", _doc({"tput": {"value": 80000.0, "unit": "rps/core"}}))
    gain = _write(
        tmp_path, "gain.json", _doc({"tput": {"value": 500000.0, "unit": "rps/core"}})
    )
    assert _run(base, ok).returncode == 0
    r = _run(base, bad)
    assert r.returncode == 1
    assert "fell below baseline" in r.stdout
    assert _run(base, gain).returncode == 0


def test_informational_units_never_gate(tmp_path):
    # Units outside the gated tables (like the transport bench's `desc`
    # in-flight depth, or `req/s` aggregates) are trajectory-only: a wild
    # swing in either direction must not fail the gate — but a gated
    # metric in the same document still does.
    base = _write(
        tmp_path,
        "base.json",
        _doc(
            {
                "qp echo mean in-flight": {"value": 6.0, "unit": "desc"},
                "aggregate": {"value": 100000.0, "unit": "req/s"},
                "hotpath": {"value": 2000.0, "unit": "ns/req"},
            }
        ),
    )
    wild = _write(
        tmp_path,
        "wild.json",
        _doc(
            {
                "qp echo mean in-flight": {"value": 0.01, "unit": "desc"},
                "aggregate": {"value": 5.0, "unit": "req/s"},
                "hotpath": {"value": 2000.0, "unit": "ns/req"},
            }
        ),
    )
    r = _run(base, wild)
    assert r.returncode == 0, r.stdout + r.stderr
    both = _write(
        tmp_path,
        "both.json",
        _doc(
            {
                "qp echo mean in-flight": {"value": 0.01, "unit": "desc"},
                "aggregate": {"value": 5.0, "unit": "req/s"},
                "hotpath": {"value": 9000.0, "unit": "ns/req"},
            }
        ),
    )
    r = _run(base, both)
    assert r.returncode == 1
    assert "hotpath" in r.stdout


def test_checked_in_obs_baseline_gates_ns_per_req(tmp_path):
    # The observability-overhead baseline (BENCH_obs.json) must actually
    # gate: both recorder modes use the gated ns/req unit, in-envelope
    # numbers pass, and a runaway traced path fails even against the wide
    # provisional rel.
    base = REPO_ROOT / "BENCH_obs.json"
    doc = json.loads(base.read_text())
    gated = {k: v for k, v in doc["metrics"].items() if not k.startswith("_")}
    assert {"hot path untraced", "hot path traced (1/1024)"} <= set(gated)
    assert all(v["unit"] == "ns/req" for v in gated.values())
    ok = _write(
        tmp_path,
        "ok.json",
        _doc(
            {k: {"value": v["value"], "unit": v["unit"]} for k, v in gated.items()},
            bench="obs_overhead",
        ),
    )
    assert _run(base, ok).returncode == 0
    bad = _write(
        tmp_path,
        "bad.json",
        _doc(
            {k: {"value": v["value"] * 100.0, "unit": v["unit"]} for k, v in gated.items()},
            bench="obs_overhead",
        ),
    )
    r = _run(base, bad)
    assert r.returncode == 1
    assert "PERF REGRESSION" in r.stdout


def test_bad_usage_and_bad_json_exit_2(tmp_path):
    assert _run().returncode == 2
    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    cur = _write(tmp_path, "cur.json", _doc({}))
    assert _run(garbage, cur).returncode == 2
