"""AOT path: artifacts lower to valid HLO text, parse back through the XLA
client, and execute with the same numerics as the jax model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts():
    """Lower all artifacts once per test module."""
    return {name: (low, ins, outs) for name, low, ins, outs in aot.build_artifacts()}


def test_manifest_covers_expected_artifacts(artifacts):
    assert set(artifacts) == {"model_b1", "model_b2", "model_b4", "conv_tile"}


def test_hlo_text_parses_and_has_entry(artifacts):
    for name, (low, _ins, _outs) in artifacts.items():
        text = aot.to_hlo_text(low)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # Pallas kernels must have lowered to plain HLO ops (interpret
        # mode), never to a Mosaic custom-call the CPU client can't run.
        assert "mosaic" not in text.lower(), name


def test_hlo_executes_with_model_numerics(artifacts):
    # Execute the lowered artifact (same computation the rust PJRT client
    # compiles from the HLO text) and compare to the oracle-path jax model.
    low, _ins, _outs = artifacts["model_b1"]
    exe = low.compile()
    x = jax.random.normal(jax.random.PRNGKey(42), (1,) + model.IN_SHAPE, jnp.float32)
    (got,) = exe(x)
    params = model.init_params(seed=0)
    want = np.asarray(model.forward_batch(params, x, use_pallas=False))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    # And the HLO text itself is well-formed for the rust loader.
    assert "ENTRY" in aot.to_hlo_text(low)


def test_written_artifacts_match_rebuild(tmp_path):
    # main() writes files; rebuilding produces identical bytes (determinism
    # of the baked weights / lowering).
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--outdir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    files = sorted(os.listdir(tmp_path))
    assert "manifest.txt" in files
    assert "model_b1.hlo.txt" in files
    m = (tmp_path / "manifest.txt").read_text()
    assert "model_b1 in=1x3x32x32 out=1x10" in m
    assert "conv_tile in=3x32x32 out=16x14x14" in m
