"""L1 correctness: Pallas tiled conv kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, tilings, strides and dtypes; every case asserts
allclose against ``ref.conv2d_ref`` — the core correctness signal of the
compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv2d_tiled import (
    conv2d_tiled,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def _check(n, h, w, m, k, stride, tm, tn, dtype=jnp.float32, tol=1e-4):
    x = _rand(0, (n, h, w), dtype)
    wt = _rand(1, (m, n, k, k), dtype)
    got = conv2d_tiled(x, wt, tm=tm, tn=tn, stride=stride)
    want = ref.conv2d_ref(x, wt, stride=stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


# ---- deterministic cases mirroring the paper's layer shapes (scaled) ----

def test_basic_3x3():
    _check(n=8, h=12, w=12, m=16, k=3, stride=1, tm=8, tn=4)


def test_one_by_one_kernel():
    # SqueezeNet-style 1x1 conv (the Figure 15(b) compute-bound case).
    _check(n=16, h=9, w=9, m=12, k=1, stride=1, tm=4, tn=8)


def test_strided_like_alexnet_conv1():
    # AlexNet conv1 shape family: large K, stride > 1, N=3.
    _check(n=3, h=19, w=19, m=8, k=5, stride=2, tm=8, tn=3)


def test_tiles_not_dividing_channels():
    # Padding path: Tm/Tn not dividing M/N.
    _check(n=7, h=10, w=10, m=9, k=3, stride=1, tm=4, tn=3)


def test_tile_larger_than_dim():
    _check(n=3, h=8, w=8, m=5, k=3, stride=1, tm=16, tn=16)


def test_single_channel_tiles():
    _check(n=4, h=8, w=8, m=4, k=3, stride=1, tm=1, tn=1)


def test_rectangular_input():
    x = _rand(0, (4, 9, 15), jnp.float32)
    wt = _rand(1, (6, 4, 3, 3), jnp.float32)
    got = conv2d_tiled(x, wt, tm=3, tn=2)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, wt), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
def test_dtypes(dtype, tol):
    _check(n=4, h=10, w=10, m=8, k=3, stride=1, tm=4, tn=2, dtype=dtype, tol=tol)


# ---- hypothesis sweep ----

@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 12),
    m=st.integers(1, 12),
    k=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    extra=st.integers(0, 6),
    tm=st.integers(1, 16),
    tn=st.integers(1, 16),
    data=st.data(),
)
def test_kernel_matches_ref_swept(n, m, k, stride, extra, tm, tn, data):
    h = k + stride * data.draw(st.integers(1, 5)) + extra
    _check(n=n, h=h, w=h, m=m, k=k, stride=stride, tm=tm, tn=tn)


# ---- structural (§Perf/L1) helpers ----

def test_vmem_footprint_monotone_in_tiles():
    a = vmem_footprint_bytes(8, 8, 32, 32, 3, 30, 30)
    b = vmem_footprint_bytes(16, 8, 32, 32, 3, 30, 30)
    assert b > a
    assert a > 0


def test_mxu_utilization_estimate_bounds():
    assert mxu_utilization_estimate(128, 128) == 1.0
    assert mxu_utilization_estimate(8, 3) == pytest.approx(24 / 16384)
    assert mxu_utilization_estimate(256, 256) == 1.0  # capped
