"""L2 correctness: TinyCNN forward — Pallas path vs pure-jnp oracle path,
shape contracts, and determinism of the baked parameters."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _img(seed, batch=None):
    shape = ((batch,) if batch else ()) + model.IN_SHAPE
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_params_deterministic():
    a = model.init_params(seed=0)
    b = model.init_params(seed=0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = model.init_params(seed=1)
    assert any(not np.array_equal(a[k], c[k]) for k in a)


def test_param_shapes_match_layer_table():
    p = model.init_params()
    for name, m, n, k, _s, _tm, _tn in model.LAYERS:
        assert p[name].shape == (m, n, k, k)


def test_forward_single_shape_and_finite():
    p = model.init_params()
    y = model.forward_single(p, _img(3))
    assert y.shape == (model.NUM_CLASSES,)
    assert np.all(np.isfinite(y))


def test_pallas_path_matches_ref_path():
    # The L2 signal: swapping Pallas convs for oracle convs is a no-op.
    p = model.init_params()
    x = _img(7)
    got = model.forward_single(p, x, use_pallas=True)
    want = model.forward_single(p, x, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_forward_batch_equals_per_image():
    p = model.init_params()
    xs = _img(11, batch=3)
    ys = model.forward_batch(p, xs, use_pallas=False)
    assert ys.shape == (3, model.NUM_CLASSES)
    for i in range(3):
        np.testing.assert_allclose(
            ys[i], model.forward_single(p, xs[i], use_pallas=False),
            rtol=1e-5, atol=1e-5,
        )


def test_conv_layer_single_shape():
    p = model.init_params()
    y = model.conv_layer_single(p, _img(5))
    assert y.shape == (16, 14, 14)  # (32-5)//2+1 = 14


def test_batch_jit_traces():
    p = model.init_params()
    fn = jax.jit(lambda xs: model.forward_batch(p, xs, use_pallas=False))
    y = fn(_img(9, batch=2))
    assert y.shape == (2, model.NUM_CLASSES)
