"""L2: the CNN forward pass in JAX, calling the L1 Pallas kernel.

The AOT artifact model is **TinyCNN** — an AlexNet-shaped conv stack scaled
to run fast under interpret-mode Pallas on CPU (the full-size networks are
modeled and simulated on the rust side; the artifact proves the three-layer
stack composes and carries real numerics end-to-end). Weights are generated
deterministically (seed 0) at AOT time and baked into the HLO as constants,
so the rust request path feeds images only.

Layer stack (32×32×3 input, 10 classes):
  conv1: 16×3×5×5 /s2 → ReLU          (Pallas, tm=8,  tn=3)
  pool /2
  conv2: 32×16×3×3    → ReLU          (Pallas, tm=16, tn=8)
  pool /2
  conv3: 10×32×1×1                    (Pallas, tm=10, tn=16)
  global average pool → logits [10]
"""

import jax
import jax.numpy as jnp

from .kernels.conv2d_tiled import conv2d_tiled
from .kernels import ref

#: Image input shape (channels, height, width).
IN_SHAPE = (3, 32, 32)
#: Number of classes.
NUM_CLASSES = 10

#: (name, out_ch, in_ch, k, stride, tm, tn) per conv layer.
LAYERS = (
    ("conv1", 16, 3, 5, 2, 8, 3),
    ("conv2", 32, 16, 3, 1, 16, 8),
    ("conv3", 10, 32, 1, 1, 10, 16),
)


def init_params(seed: int = 0):
    """He-style deterministic init for the three conv layers."""
    params = {}
    key = jax.random.PRNGKey(seed)
    for name, m, n, k, _s, _tm, _tn in LAYERS:
        key, sub = jax.random.split(key)
        fan_in = n * k * k
        params[name] = jax.random.normal(sub, (m, n, k, k), jnp.float32) * (
            2.0 / fan_in
        ) ** 0.5
    return params


def forward_single(params, x, *, use_pallas: bool = True, interpret: bool = True):
    """Forward one image ``[3, 32, 32] -> [10]`` logits.

    ``use_pallas=False`` swaps every conv for the pure-jnp oracle — the L2
    correctness reference.
    """
    conv = (
        (lambda x, w, s, tm, tn: conv2d_tiled(x, w, tm=tm, tn=tn, stride=s,
                                              interpret=interpret))
        if use_pallas
        else (lambda x, w, s, tm, tn: ref.conv2d_ref(x, w, stride=s))
    )
    (n1, _, _, _, s1, tm1, tn1) = LAYERS[0]
    h = conv(x, params["conv1"], s1, tm1, tn1)
    h = ref.relu_ref(h)
    h = ref.maxpool2_ref(h)
    (_, _, _, _, s2, tm2, tn2) = LAYERS[1]
    h = conv(h, params["conv2"], s2, tm2, tn2)
    h = ref.relu_ref(h)
    h = ref.maxpool2_ref(h)
    (_, _, _, _, s3, tm3, tn3) = LAYERS[2]
    h = conv(h, params["conv3"], s3, tm3, tn3)
    return ref.global_avgpool_ref(h)


def forward_batch(params, xs, **kw):
    """Forward ``[B, 3, 32, 32] -> [B, 10]`` (the serving entry point).

    The batch loop is unrolled at trace time (B is static) — the FPGA
    engine's loop F of Figure 5(a).
    """
    return jnp.stack([forward_single(params, xs[i], **kw) for i in range(xs.shape[0])])


def conv_layer_single(params, x, *, interpret: bool = True):
    """Standalone conv1 (the per-layer artifact): [3,32,32] -> [16,14,14]."""
    (_, _, _, _, s1, tm1, tn1) = LAYERS[0]
    return conv2d_tiled(x, params["conv1"], tm=tm1, tn=tn1, stride=s1,
                        interpret=interpret)
