"""L1: the paper's tiled convolution engine as a Pallas kernel.

The accelerator of §3 ② is a ``Tm×Tn`` MAC array fed from double-buffered
BRAM tiles, iterated by the loop nest of Figure 5(a): OFM channels (D),
IFM channels (C, the accumulation loop), rows/cols (E). The Pallas mapping
(DESIGN.md §Hardware-Adaptation):

* loop D → grid axis 0 (``⌈M/Tm⌉``), loop C → grid axis 1 (``⌈N/Tn⌉``,
  the innermost / reduction axis, exactly like Figure 5's inner loop);
* BRAM tile buffers → VMEM blocks via BlockSpec: the weight block is
  ``(Tm, Tn, K, K)`` (the paper's ``W[Tm][Tn][K][K]``), the OFM block is
  ``(Tm, R, C)`` — i.e. ``Tr = R, Tc = C``: the artifact models are small
  enough that a full row-plane fits VMEM, collapsing loop E (the rust-side
  analytic model and simulator keep the general Tr/Tc);
* the ``Tm×Tn`` DSP array → the MXU: each (kh, kw) tap contracts the Tn
  axis with a ``(Tm, Tn) × (Tn, R·C)`` matmul — MXU-systolic-shaped work
  instead of the paper's DSP broadcast tree;
* the double buffer → the Pallas grid pipeline (automatic on real TPUs;
  under ``interpret=True`` we validate structure + numerics only).

The kernel MUST be lowered with ``interpret=True`` here: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, r: int, c: int):
    """One grid step: accumulate a (Tm, Tn) tile-pair into the OFM block.

    x_ref: (Tn, H, W) IFM tile      — the paper's I[Tn][Tr][Tc] buffer
    w_ref: (Tm, Tn, K, K) weights   — the paper's W[Tm][Tn][K][K] buffer
    o_ref: (Tm, R, C) OFM tile      — the paper's O[Tm][Tr][Tc] buffer
    """
    # Loop C is the reduction axis: zero the accumulator on its first trip.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...]
    tm = w.shape[0]
    acc = jnp.zeros((tm, r, c), dtype=jnp.float32)
    # K×K tap loop (the engine's tComp = K·K·Tr·Tc schedule, eq 11): each
    # tap is a Tn-contraction — an MXU matmul of (Tm,Tn)·(Tn,R·C).
    for kh in range(k):
        for kw in range(k):
            # Static strided slice: (Tn, R, C) patch for this tap.
            patch = jax.lax.slice(
                x,
                (0, kh, kw),
                (x.shape[0], kh + (r - 1) * stride + 1, kw + (c - 1) * stride + 1),
                (1, stride, stride),
            )
            tap = w[:, :, kh, kw]  # (Tm, Tn)
            acc = acc + jax.lax.dot_general(
                tap,
                patch.reshape(patch.shape[0], r * c),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(tm, r, c)
    o_ref[...] += acc.astype(o_ref.dtype)


def conv2d_tiled(x, w, *, tm: int, tn: int, stride: int = 1, interpret: bool = True):
    """Tiled 2D convolution via Pallas (VALID padding, NCHW-sans-batch).

    Args:
      x: ``[N, H, W]`` IFM.
      w: ``[M, N, K, K]`` weights.
      tm, tn: the paper's OFM/IFM channel tiling parameters.
      stride: spatial stride.
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      ``[M, R, C]`` OFM, same dtype as ``x``.
    """
    n_in, h, w_in = x.shape
    m, n_w, k, k2 = w.shape
    assert k == k2, "square kernels only"
    assert n_w == n_in, f"channel mismatch: {n_w} != {n_in}"
    assert 1 <= tm and 1 <= tn, "tiles must be positive"
    r = (h - k) // stride + 1
    c = (w_in - k) // stride + 1
    assert r > 0 and c > 0, "kernel larger than input"

    # Pad channels up to tile multiples so every block is full (the HLS
    # engine pads tiles the same way — see sim::engine).
    m_pad = math.ceil(m / tm) * tm
    n_pad = math.ceil(n_in / tn) * tn
    if n_pad != n_in:
        x = jnp.pad(x, ((0, n_pad - n_in), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, n_pad - n_in), (0, 0), (0, 0)))
    if m_pad != m:
        w = jnp.pad(w, ((0, m_pad - m), (0, 0), (0, 0), (0, 0)))

    grid = (m_pad // tm, n_pad // tn)  # (loop D, loop C)
    out = pl.pallas_call(
        functools.partial(_conv_kernel, k=k, stride=stride, r=r, c=c),
        grid=grid,
        in_specs=[
            # IFM tile: Tn channels, full plane (Tr=R, Tc=C).
            pl.BlockSpec((tn, h, w_in), lambda i, j: (j, 0, 0)),
            # Weight tile: (Tm, Tn, K, K).
            pl.BlockSpec((tm, tn, k, k), lambda i, j: (i, j, 0, 0)),
        ],
        # OFM tile revisited across the reduction axis j.
        out_specs=pl.BlockSpec((tm, r, c), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, r, c), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:m]


def vmem_footprint_bytes(tm: int, tn: int, h: int, w: int, k: int, r: int, c: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM bytes per grid step (the L1 §Perf metric): IFM block +
    weight block + OFM accumulator (×2 for the pipeline's double buffer)."""
    ifm = tn * h * w
    wei = tm * tn * k * k
    ofm = tm * r * c
    return 2 * (ifm + wei + ofm) * dtype_bytes


def mxu_utilization_estimate(tm: int, tn: int) -> float:
    """Fraction of a 128×128 MXU a (Tm, Tn) tap-matmul occupies (the L1
    §Perf structural target: ≥ 0.5 wants Tm·Tn ≥ 8192)."""
    return min(tm, 128) * min(tn, 128) / (128.0 * 128.0)
