"""Pure-jnp oracles for the Pallas kernels (the L1 correctness signal).

Every Pallas kernel in this package is checked against these references by
``python/tests/test_kernel.py`` (hypothesis sweeps over shapes/dtypes).
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, stride=1):
    """Plain 2D convolution (no padding / 'VALID'), NCHW-without-batch.

    Args:
      x: ``[N, H, W]`` input feature map (IFM channels first).
      w: ``[M, N, K, K]`` weights.
      stride: spatial stride.

    Returns:
      ``[M, R, C]`` output feature map with ``R = (H-K)//stride + 1``.
    """
    out = jax.lax.conv_general_dilated(
        x[None],  # add batch dim
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32,
    )
    return out[0].astype(x.dtype)


def relu_ref(x):
    """ReLU."""
    return jnp.maximum(x, 0)


def maxpool2_ref(x):
    """2x2 max pooling with stride 2 over the trailing two dims of [N,H,W].

    Odd trailing rows/cols are dropped (floor semantics), matching the
    accelerator's streaming pooler.
    """
    n, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2]
    x = x.reshape(n, h2, 2, w2, 2)
    return x.max(axis=(2, 4))


def global_avgpool_ref(x):
    """Global average pooling [N, H, W] -> [N]."""
    return x.mean(axis=(1, 2))
