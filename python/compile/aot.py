"""AOT compile path: lower the L2 model (with L1 Pallas kernels inlined) to
HLO **text** artifacts the rust runtime loads via PJRT.

HLO text — NOT ``lowered.compile()``/``.serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids, which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (written to ``--outdir``, default ``../artifacts``):
  model_b1.hlo.txt   TinyCNN forward, batch 1:  f32[1,3,32,32]  -> f32[1,10]
  model_b2.hlo.txt   TinyCNN forward, batch 2:  f32[2,3,32,32]  -> f32[2,10]
  model_b4.hlo.txt   TinyCNN forward, batch 4:  f32[4,3,32,32]  -> f32[4,10]
  conv_tile.hlo.txt  standalone conv1 layer:    f32[3,32,32]    -> f32[16,14,14]
  manifest.txt       one line per artifact: name, input shape, output shape

Weights are baked as constants (deterministic seed 0), so python never
runs at request time. Run via ``make artifacts`` (no-op when up to date).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is essential: the default printer elides
    big literals as ``constant({...})``, which the text parser then reads
    back as ZEROS — silently wiping the baked model weights.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def build_artifacts():
    """Yield (name, lowered) for every artifact."""
    params = model.init_params(seed=0)

    for b in (1, 2, 4):
        spec = jax.ShapeDtypeStruct((b,) + model.IN_SHAPE, jnp.float32)
        fn = lambda xs: (model.forward_batch(params, xs),)
        yield (
            f"model_b{b}",
            jax.jit(fn).lower(spec),
            (b,) + model.IN_SHAPE,
            (b, model.NUM_CLASSES),
        )

    spec = jax.ShapeDtypeStruct(model.IN_SHAPE, jnp.float32)
    fn1 = lambda x: (model.conv_layer_single(params, x),)
    yield ("conv_tile", jax.jit(fn1).lower(spec), model.IN_SHAPE, (16, 14, 14))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = []
    for name, lowered, in_shape, out_shape in build_artifacts():
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shape_s = "x".join(map(str, in_shape))
        out_s = "x".join(map(str, out_shape))
        manifest.append(f"{name} in={shape_s} out={out_s}")
        print(f"wrote {path} ({len(text)} chars)  {shape_s} -> {out_s}")

    # Golden cross-language check: a deterministic image and its oracle
    # logits, so the rust runtime can verify end-to-end numerics.
    import numpy as np

    params = model.init_params(seed=0)
    n_elems = int(np.prod(model.IN_SHAPE))
    x = (np.arange(n_elems, dtype=np.float32) % 17 - 8.0) / 8.0
    x = jnp.asarray(x.reshape((1,) + model.IN_SHAPE))
    golden = np.asarray(model.forward_batch(params, x, use_pallas=False))[0]
    with open(os.path.join(args.outdir, "golden.txt"), "w") as f:
        f.write("# input: ((arange(3*32*32) % 17) - 8) / 8, reshaped 1x3x32x32\n")
        f.write(" ".join(f"{v:.8e}" for v in golden) + "\n")
    print(f"wrote {os.path.join(args.outdir, 'golden.txt')}")

    with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(args.outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
