"""§Perf for L1 (Pallas kernel structure) and L2 (lowered HLO quality).

Interpret-mode wallclock is CPU-numpy time, NOT a TPU proxy, so L1 is
profiled structurally: VMEM footprint per grid step (must fit the ~16 MiB
VMEM of a TPU core with double-buffer headroom) and MXU-utilization
estimate of the per-tap contraction. L2 is profiled by inspecting the
lowered HLO: op census, fusion opportunities left on the table, and
constant/recompute sanity.

Run: cd python && python -m compile.perf_report
"""

import collections
import re

import jax
import jax.numpy as jnp

from . import aot, model
from .kernels.conv2d_tiled import mxu_utilization_estimate, vmem_footprint_bytes

VMEM_BYTES = 16 * 1024 * 1024  # one TPU core


def l1_report():
    print("== L1: Pallas kernel structure (per conv layer of TinyCNN) ==")
    print(f"{'layer':<8} {'tile (Tm,Tn)':<14} {'VMEM/step':<12} {'of 16MiB':<9} {'MXU est.':<9}")
    shapes = {"conv1": (32, 32, 14), "conv2": (14, 14, 12), "conv3": (6, 6, 6)}
    for name, m, n, k, s, tm, tn in model.LAYERS:
        h, w, r = shapes[name]
        v = vmem_footprint_bytes(tm, tn, h, w, k, r, r)
        u = mxu_utilization_estimate(tm, tn)
        print(
            f"{name:<8} ({tm:>3},{tn:>3})     {v/1024:>8.1f}KiB  {v/VMEM_BYTES*100:>6.2f}%  {u*100:>6.2f}%"
        )
    # The production-scale tiling the rust side deploys (⟨128,10⟩ on
    # AlexNet-class layers): VMEM + MXU at realistic sizes.
    v = vmem_footprint_bytes(128, 10, 31, 31, 3, 27, 27, dtype_bytes=2)
    u = mxu_utilization_estimate(128, 10)
    print(
        f"{'alex-cls':<8} (128, 10)     {v/1024:>8.1f}KiB  {v/VMEM_BYTES*100:>6.2f}%  {u*100:>6.2f}%"
    )
    print(
        "note: MXU estimate is the (Tm×Tn)/(128×128) occupancy of one tap-matmul;\n"
        "the K·K taps pipeline back-to-back, so temporal utilization is higher.\n"
    )


def l2_report():
    print("== L2: lowered HLO census (model_b1) ==")
    (_, lowered, _, _) = next(iter(aot.build_artifacts()))
    text = aot.to_hlo_text(lowered)
    ops = collections.Counter(
        m.group(1)
        for m in re.finditer(r"=\s+[a-z0-9\[\],{}()/*\s]+?([a-z\-]+)\(", text)
    )
    total = sum(ops.values())
    print(f"instructions: {total}")
    for op, n in ops.most_common(12):
        print(f"  {op:<22} {n}")
    n_while = text.count(" while(")
    n_dot = ops.get("dot", 0)
    n_custom = text.lower().count("custom-call")
    print(f"while loops (pallas grids): {n_while}  dots: {n_dot}  custom-calls: {n_custom}")
    assert n_custom == 0, "mosaic custom-call would not run on CPU PJRT"
    # Recompute sanity: the three conv weights appear exactly once each as
    # constants (no duplicated weight materialization).
    consts = len(re.findall(r"f32\[\d+,\d+,\d+,\d+\]\{3,2,1,0\} constant\(", text))
    print(f"4-D weight constants materialized: {consts} (expect 3: conv1..conv3)")


if __name__ == "__main__":
    jax.config.update("jax_platforms", "cpu")
    l1_report()
    l2_report()
